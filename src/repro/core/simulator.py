"""Simulators for the Generalized AsyncSGD queueing network.

Two complementary implementations:

  * ``AsyncNetworkSim`` — an exact discrete-event simulation with per-task
    identity (heap-based, host Python).  Supports every service-time law in
    the timing-law registry (``repro.scenario.laws``: the Section 5.3.3
    exponential / deterministic / lognormal built-ins plus extensions such
    as the hyperexponential H2), the optional
    CS-side FIFO buffer (Section 7), phase-dependent energy accounting
    (Eq. 14), and measures the *relative delay* exactly as defined in
    Section 2.4.  It doubles as the virtual-time engine of the FL trainer
    (``repro.fl.trainer``): ``next_update()`` yields one model-update event
    at a time.

  * ``jump_chain_throughput`` — historical CTMC jump-chain entry point, now
    a thin wrapper over the jitted event engine (``repro.core.events``),
    which races per-task service clocks exactly for *every* service law and
    therefore subsumes the count-state sampler.

Reference contract: ``AsyncNetworkSim`` is the exact per-task-identity
reference implementation that the device engine ``repro.core.events`` (and
the fused trainer ``repro.fl.engine``) are cross-checked against.  The two
consume randomness differently (numpy heap order vs. split JAX keys), so
the agreement is distributional — throughput, per-client mean relative
delay, energy and occupancy match within Monte-Carlo tolerance on every
service law (``tests/test_events.py``).  Behavioural changes here must be
mirrored in ``repro.core.events``.
"""
from __future__ import annotations

import dataclasses
import heapq
from typing import Callable, Iterator, Optional

import numpy as np

from ..scenario.laws import get_law
from .buzen import NetworkParams

# event kinds
_DOWN, _COMP, _UP, _CS = 0, 1, 2, 3


def make_sampler(kind: str, rng: np.random.Generator) -> Callable[[float], float]:
    """Host sampler for service times with mean ``1/mu``.

    ``kind`` names a law in the timing-law registry
    (``repro.scenario.laws``: the Section 5.3.3 built-ins plus any
    ``@timing_law``-registered extension such as ``"hyperexponential"``);
    unknown names raise *eagerly* with the registered options.  The
    returned sampler raises ``ValueError`` on a non-positive rate instead
    of silently emitting ``inf``/NaN service times (a zero rate would
    otherwise stall the event heap with infinite clocks).
    """
    law = get_law(kind)
    return lambda mu: law.host_sample(mu, rng)


@dataclasses.dataclass
class UpdateEvent:
    """One model-parameter update at the CS (end of a round)."""

    round: int           # round index k (0-based): this is update number k
    client: int          # C_k — client whose gradient is applied
    dispatch_round: int  # round counter value when the task was dispatched
    time: float          # wall-clock time of the update
    task_id: int = -1    # identity of the completed task (payload key)

    @property
    def relative_delay(self) -> int:
        return self.round - self.dispatch_round


@dataclasses.dataclass
class SimStats:
    updates: int
    time: float
    throughput: float
    # [n] unscaled per-client conditional mean delay E0[R_i], 0 where no
    # samples; E0[D_i] of Theorem 2 is p_i * mean_delay[i]
    mean_delay: np.ndarray
    delay_counts: np.ndarray        # [n] number of updates per client
    energy: float
    mean_queue_counts: np.ndarray   # [3n(+1)] time-averaged station occupancy


class AsyncNetworkSim:
    """Discrete-event simulation of the closed network of Fig. 1 / Fig. 6."""

    def __init__(
        self,
        params: NetworkParams,
        m: int,
        *,
        distribution: str = "exponential",
        seed: int = 0,
        power: Optional[object] = None,  # energy.PowerProfile or None
    ):
        self.p = np.asarray(params.p, dtype=np.float64)
        self.p = self.p / self.p.sum()
        self.mu_c = np.asarray(params.mu_c, dtype=np.float64)
        self.mu_d = np.asarray(params.mu_d, dtype=np.float64)
        self.mu_u = np.asarray(params.mu_u, dtype=np.float64)
        self.mu_cs = None if params.mu_cs is None else float(params.mu_cs)
        self.n = len(self.p)
        self.m = m
        self.rng = np.random.default_rng(seed)
        self.sample = make_sampler(distribution, self.rng)
        self.power = power

        self.t = 0.0
        self.round = 0
        self.heap: list = []  # (time, seq, kind, client, task_id)
        self._seq = 0
        self.comp_queue: list[list[int]] = [[] for _ in range(self.n)]  # FIFO of task ids
        self.comp_busy = np.zeros(self.n, dtype=bool)
        self.cs_queue: list[tuple[int, int]] = []  # (task_id, client)
        self.cs_busy = False
        self.task_dispatch_round: dict[int, int] = {}
        self._next_task = 0

        # statistics
        self.delay_sum = np.zeros(self.n)
        self.delay_cnt = np.zeros(self.n, dtype=np.int64)
        self.energy = 0.0
        self.n_down = np.zeros(self.n, dtype=np.int64)
        self.n_up = np.zeros(self.n, dtype=np.int64)
        self._occ_int = np.zeros(3 * self.n + 1)
        self._last_t = 0.0

        # initial out-of-equilibrium dispatch: m tasks uniformly at random
        # into the downlink servers (Section 5.3.3)
        self.initial_tasks: list[tuple[int, int]] = []  # (client, task_id)
        for _ in range(m):
            client = int(self.rng.integers(self.n))
            tid = self._dispatch(client)
            self.initial_tasks.append((client, tid))

    # -- internals ----------------------------------------------------------

    def _push(self, dt: float, kind: int, client: int, task_id: int):
        self._seq += 1
        heapq.heappush(self.heap, (self.t + dt, self._seq, kind, client, task_id))

    def _dispatch(self, client: int) -> int:
        task_id = self._next_task
        self._next_task += 1
        self.task_dispatch_round[task_id] = self.round
        self.n_down[client] += 1
        self._push(self.sample(self.mu_d[client]), _DOWN, client, task_id)
        return task_id

    def _start_compute(self, client: int):
        if not self.comp_busy[client] and self.comp_queue[client]:
            task_id = self.comp_queue[client].pop(0)
            self.comp_busy[client] = True
            self._push(self.sample(self.mu_c[client]), _COMP, client, task_id)

    def _start_cs(self):
        if not self.cs_busy and self.cs_queue:
            task_id, client = self.cs_queue.pop(0)
            self.cs_busy = True
            self._push(self.sample(self.mu_cs), _CS, client, task_id)

    def _instantaneous_power(self) -> float:
        if self.power is None:
            return 0.0
        P_c = np.asarray(self.power.P_c)
        P_u = np.asarray(self.power.P_u)
        P_d = np.asarray(self.power.P_d)
        val = float(np.sum(P_c * self.comp_busy) + np.sum(P_u * self.n_up)
                    + np.sum(P_d * self.n_down))
        if self.power.P_cs is not None and self.cs_busy:
            val += float(self.power.P_cs)
        return val

    def _advance_time(self, new_t: float):
        dt = new_t - self._last_t
        if dt > 0:
            self.energy += dt * self._instantaneous_power()
            occ = np.concatenate([
                self.n_down.astype(float),
                np.array([len(q) for q in self.comp_queue], dtype=float)
                + self.comp_busy.astype(float),
                self.n_up.astype(float),
                np.array([len(self.cs_queue) + float(self.cs_busy)]),
            ])
            self._occ_int += dt * occ
            self._last_t = new_t
        self.t = new_t

    # -- public -------------------------------------------------------------

    def next_update(self) -> UpdateEvent:
        """Advance until the next model-parameter update and return it.

        The caller is responsible for calling :meth:`dispatch_next` (routing
        a fresh task) after consuming the event — the FL trainer does this so
        it can record which parameter version travels with the task.  For
        plain statistics collection use :meth:`run`.
        """
        while True:
            time, _, kind, client, task_id = heapq.heappop(self.heap)
            self._advance_time(time)
            if kind == _DOWN:
                self.n_down[client] -= 1
                self.comp_queue[client].append(task_id)
                self._start_compute(client)
            elif kind == _COMP:
                self.comp_busy[client] = False
                self._start_compute(client)
                self.n_up[client] += 1
                self._push(self.sample(self.mu_u[client]), _UP, client, task_id)
            elif kind == _UP:
                self.n_up[client] -= 1
                if self.mu_cs is None:
                    return self._apply_update(client, task_id)
                self.cs_queue.append((task_id, client))
                self._start_cs()
            elif kind == _CS:
                self.cs_busy = False
                ev = self._apply_update(client, task_id)
                self._start_cs()
                return ev

    def _apply_update(self, client: int, task_id: int) -> UpdateEvent:
        dispatch_round = self.task_dispatch_round.pop(task_id)
        ev = UpdateEvent(round=self.round, client=client,
                         dispatch_round=dispatch_round, time=self.t,
                         task_id=task_id)
        self.round += 1
        self.delay_sum[client] += ev.relative_delay
        self.delay_cnt[client] += 1
        return ev

    def dispatch_next(self) -> tuple[int, int]:
        """Route a fresh task according to ``p`` (Algorithm 1, lines 7–8).

        Returns ``(client, task_id)`` so callers can attach a payload (the
        parameter snapshot travelling with the task)."""
        client = int(self.rng.choice(self.n, p=self.p))
        tid = self._dispatch(client)
        return client, tid

    def run(self, num_updates: int, *, warmup: int = 0) -> SimStats:
        """Collect stationary statistics over ``num_updates`` rounds."""
        for k in range(warmup):
            self.next_update()
            self.dispatch_next()
        # reset statistics after warmup
        self.delay_sum[:] = 0
        self.delay_cnt[:] = 0
        self.energy = 0.0
        self._occ_int[:] = 0
        t0 = self.t
        self._last_t = self.t
        for k in range(num_updates):
            self.next_update()
            self.dispatch_next()
        horizon = self.t - t0
        mean_delay = np.where(self.delay_cnt > 0,
                              self.delay_sum / np.maximum(self.delay_cnt, 1), 0.0)
        return SimStats(
            updates=num_updates,
            time=horizon,
            throughput=num_updates / horizon if horizon > 0 else 0.0,
            mean_delay=mean_delay,
            delay_counts=self.delay_cnt.copy(),
            energy=self.energy,
            mean_queue_counts=self._occ_int / max(horizon, 1e-12),
        )


# ---------------------------------------------------------------------------
# JAX sampler entry point (subsumed by repro.core.events)
# ---------------------------------------------------------------------------

def jump_chain_throughput(params: NetworkParams, m: int, steps: int,
                          seed: int = 0) -> tuple[float, np.ndarray]:
    """Monte-Carlo estimate of ``lambda`` and mean station counts on device.

    Historically a CTMC jump-chain sampler over the count state space
    (exponential case only); now delegates to the jitted event engine
    (:mod:`repro.core.events`), which races per-task service clocks exactly
    — distributionally identical in the memoryless case and exact for every
    other service law.  ``steps`` is interpreted as an event budget, the
    first third of which is discarded as warmup, matching the old contract.

    Returns ``(lambda, mean_counts)`` with ``mean_counts`` of shape
    ``[3n]`` (downlink / computation / uplink per client), summing to ``m``.
    """
    from .events import simulate_stats

    mult = 4 if params.mu_cs is not None else 3
    total_updates = max(steps // mult, 1)
    warmup = total_updates // 3
    stats = simulate_stats(params, m, total_updates - warmup, warmup=warmup,
                           seed=seed)
    return float(stats.throughput), np.asarray(stats.mean_queue_counts[:-1])
