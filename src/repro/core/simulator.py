"""Simulators for the Generalized AsyncSGD queueing network.

Two complementary implementations:

  * ``AsyncNetworkSim`` — an exact discrete-event simulation with per-task
    identity (heap-based, host Python).  Supports exponential, deterministic
    and lognormal service/communication times (Section 5.3.3), the optional
    CS-side FIFO buffer (Section 7), phase-dependent energy accounting
    (Eq. 14), and measures the *relative delay* exactly as defined in
    Section 2.4.  It doubles as the virtual-time engine of the FL trainer
    (``repro.fl.trainer``): ``next_update()`` yields one model-update event
    at a time.

  * ``jump_chain_throughput`` — a JAX ``lax.scan`` CTMC jump-chain sampler
    over the count state space (exponential case only); a fast, fully
    vectorizable cross-check of the product-form stationary distribution and
    of the throughput formula (Prop. 4).
"""
from __future__ import annotations

import dataclasses
import heapq
import math
from typing import Callable, Iterator, Optional

import numpy as np

from .buzen import NetworkParams

# event kinds
_DOWN, _COMP, _UP, _CS = 0, 1, 2, 3


def make_sampler(kind: str, rng: np.random.Generator) -> Callable[[float], float]:
    """Sample a service time with mean ``1/mu`` (Section 5.3.3 distributions)."""
    if kind == "exponential":
        return lambda mu: rng.exponential(1.0 / mu)
    if kind == "deterministic":
        return lambda mu: 1.0 / mu
    if kind == "lognormal":
        # underlying normal variance sigma_N^2 = 1, mean of LN = 1/mu
        # mean = exp(mu_N + 1/2) = 1/mu  ->  mu_N = -log(mu) - 1/2
        return lambda mu: rng.lognormal(-math.log(mu) - 0.5, 1.0)
    raise ValueError(f"unknown service distribution: {kind}")


@dataclasses.dataclass
class UpdateEvent:
    """One model-parameter update at the CS (end of a round)."""

    round: int           # round index k (0-based): this is update number k
    client: int          # C_k — client whose gradient is applied
    dispatch_round: int  # round counter value when the task was dispatched
    time: float          # wall-clock time of the update
    task_id: int = -1    # identity of the completed task (payload key)

    @property
    def relative_delay(self) -> int:
        return self.round - self.dispatch_round


@dataclasses.dataclass
class SimStats:
    updates: int
    time: float
    throughput: float
    # [n] unscaled per-client conditional mean delay E0[R_i], 0 where no
    # samples; E0[D_i] of Theorem 2 is p_i * mean_delay[i]
    mean_delay: np.ndarray
    delay_counts: np.ndarray        # [n] number of updates per client
    energy: float
    mean_queue_counts: np.ndarray   # [3n(+1)] time-averaged station occupancy


class AsyncNetworkSim:
    """Discrete-event simulation of the closed network of Fig. 1 / Fig. 6."""

    def __init__(
        self,
        params: NetworkParams,
        m: int,
        *,
        distribution: str = "exponential",
        seed: int = 0,
        power: Optional[object] = None,  # energy.PowerProfile or None
    ):
        self.p = np.asarray(params.p, dtype=np.float64)
        self.p = self.p / self.p.sum()
        self.mu_c = np.asarray(params.mu_c, dtype=np.float64)
        self.mu_d = np.asarray(params.mu_d, dtype=np.float64)
        self.mu_u = np.asarray(params.mu_u, dtype=np.float64)
        self.mu_cs = None if params.mu_cs is None else float(params.mu_cs)
        self.n = len(self.p)
        self.m = m
        self.rng = np.random.default_rng(seed)
        self.sample = make_sampler(distribution, self.rng)
        self.power = power

        self.t = 0.0
        self.round = 0
        self.heap: list = []  # (time, seq, kind, client, task_id)
        self._seq = 0
        self.comp_queue: list[list[int]] = [[] for _ in range(self.n)]  # FIFO of task ids
        self.comp_busy = np.zeros(self.n, dtype=bool)
        self.cs_queue: list[tuple[int, int]] = []  # (task_id, client)
        self.cs_busy = False
        self.task_dispatch_round: dict[int, int] = {}
        self._next_task = 0

        # statistics
        self.delay_sum = np.zeros(self.n)
        self.delay_cnt = np.zeros(self.n, dtype=np.int64)
        self.energy = 0.0
        self.n_down = np.zeros(self.n, dtype=np.int64)
        self.n_up = np.zeros(self.n, dtype=np.int64)
        self._occ_int = np.zeros(3 * self.n + 1)
        self._last_t = 0.0

        # initial out-of-equilibrium dispatch: m tasks uniformly at random
        # into the downlink servers (Section 5.3.3)
        self.initial_tasks: list[tuple[int, int]] = []  # (client, task_id)
        for _ in range(m):
            client = int(self.rng.integers(self.n))
            tid = self._dispatch(client)
            self.initial_tasks.append((client, tid))

    # -- internals ----------------------------------------------------------

    def _push(self, dt: float, kind: int, client: int, task_id: int):
        self._seq += 1
        heapq.heappush(self.heap, (self.t + dt, self._seq, kind, client, task_id))

    def _dispatch(self, client: int) -> int:
        task_id = self._next_task
        self._next_task += 1
        self.task_dispatch_round[task_id] = self.round
        self.n_down[client] += 1
        self._push(self.sample(self.mu_d[client]), _DOWN, client, task_id)
        return task_id

    def _start_compute(self, client: int):
        if not self.comp_busy[client] and self.comp_queue[client]:
            task_id = self.comp_queue[client].pop(0)
            self.comp_busy[client] = True
            self._push(self.sample(self.mu_c[client]), _COMP, client, task_id)

    def _start_cs(self):
        if not self.cs_busy and self.cs_queue:
            task_id, client = self.cs_queue.pop(0)
            self.cs_busy = True
            self._push(self.sample(self.mu_cs), _CS, client, task_id)

    def _instantaneous_power(self) -> float:
        if self.power is None:
            return 0.0
        P_c = np.asarray(self.power.P_c)
        P_u = np.asarray(self.power.P_u)
        P_d = np.asarray(self.power.P_d)
        val = float(np.sum(P_c * self.comp_busy) + np.sum(P_u * self.n_up)
                    + np.sum(P_d * self.n_down))
        if self.power.P_cs is not None and self.cs_busy:
            val += float(self.power.P_cs)
        return val

    def _advance_time(self, new_t: float):
        dt = new_t - self._last_t
        if dt > 0:
            self.energy += dt * self._instantaneous_power()
            occ = np.concatenate([
                self.n_down.astype(float),
                np.array([len(q) for q in self.comp_queue], dtype=float)
                + self.comp_busy.astype(float),
                self.n_up.astype(float),
                np.array([len(self.cs_queue) + float(self.cs_busy)]),
            ])
            self._occ_int += dt * occ
            self._last_t = new_t
        self.t = new_t

    # -- public -------------------------------------------------------------

    def next_update(self) -> UpdateEvent:
        """Advance until the next model-parameter update and return it.

        The caller is responsible for calling :meth:`dispatch_next` (routing
        a fresh task) after consuming the event — the FL trainer does this so
        it can record which parameter version travels with the task.  For
        plain statistics collection use :meth:`run`.
        """
        while True:
            time, _, kind, client, task_id = heapq.heappop(self.heap)
            self._advance_time(time)
            if kind == _DOWN:
                self.n_down[client] -= 1
                self.comp_queue[client].append(task_id)
                self._start_compute(client)
            elif kind == _COMP:
                self.comp_busy[client] = False
                self._start_compute(client)
                self.n_up[client] += 1
                self._push(self.sample(self.mu_u[client]), _UP, client, task_id)
            elif kind == _UP:
                self.n_up[client] -= 1
                if self.mu_cs is None:
                    return self._apply_update(client, task_id)
                self.cs_queue.append((task_id, client))
                self._start_cs()
            elif kind == _CS:
                self.cs_busy = False
                ev = self._apply_update(client, task_id)
                self._start_cs()
                return ev

    def _apply_update(self, client: int, task_id: int) -> UpdateEvent:
        dispatch_round = self.task_dispatch_round.pop(task_id)
        ev = UpdateEvent(round=self.round, client=client,
                         dispatch_round=dispatch_round, time=self.t,
                         task_id=task_id)
        self.round += 1
        self.delay_sum[client] += ev.relative_delay
        self.delay_cnt[client] += 1
        return ev

    def dispatch_next(self) -> tuple[int, int]:
        """Route a fresh task according to ``p`` (Algorithm 1, lines 7–8).

        Returns ``(client, task_id)`` so callers can attach a payload (the
        parameter snapshot travelling with the task)."""
        client = int(self.rng.choice(self.n, p=self.p))
        tid = self._dispatch(client)
        return client, tid

    def run(self, num_updates: int, *, warmup: int = 0) -> SimStats:
        """Collect stationary statistics over ``num_updates`` rounds."""
        for k in range(warmup):
            self.next_update()
            self.dispatch_next()
        # reset statistics after warmup
        self.delay_sum[:] = 0
        self.delay_cnt[:] = 0
        self.energy = 0.0
        self._occ_int[:] = 0
        t0 = self.t
        self._last_t = self.t
        for k in range(num_updates):
            self.next_update()
            self.dispatch_next()
        horizon = self.t - t0
        mean_delay = np.where(self.delay_cnt > 0,
                              self.delay_sum / np.maximum(self.delay_cnt, 1), 0.0)
        return SimStats(
            updates=num_updates,
            time=horizon,
            throughput=num_updates / horizon if horizon > 0 else 0.0,
            mean_delay=mean_delay,
            delay_counts=self.delay_cnt.copy(),
            energy=self.energy,
            mean_queue_counts=self._occ_int / max(horizon, 1e-12),
        )


# ---------------------------------------------------------------------------
# JAX jump-chain sampler (exponential case)
# ---------------------------------------------------------------------------

def jump_chain_throughput(params: NetworkParams, m: int, steps: int,
                          seed: int = 0) -> tuple[float, np.ndarray]:
    """CTMC jump-chain estimate of ``lambda`` and mean station counts.

    Simulates the count-state Markov chain of Prop. 1 with ``jax.lax.scan``:
    at each jump, transition rates are (per client i)
    ``mu_d[i] * x_d[i]``, ``mu_c[i] * 1{x_c[i] > 0}``, ``mu_u[i] * x_u[i]``;
    uplink completions route to a p-sampled client's downlink.  Sojourn times
    are Exp(total rate); time-weighted averages estimate E[xi] and
    ``lambda = E[sum_i mu_u[i] xi_u[i]]`` (Eq. 11).
    """
    import jax
    import jax.numpy as jnp

    n = params.n
    p = jnp.asarray(params.p) / jnp.sum(jnp.asarray(params.p))
    mu_c = jnp.asarray(params.mu_c)
    mu_d = jnp.asarray(params.mu_d)
    mu_u = jnp.asarray(params.mu_u)

    # initial state: m tasks spread over downlinks uniformly
    key = jax.random.PRNGKey(seed)
    key, k0 = jax.random.split(key)
    init_clients = jax.random.randint(k0, (m,), 0, n)
    x_d0 = jnp.zeros(n).at[init_clients].add(1.0)
    state0 = (x_d0, jnp.zeros(n), jnp.zeros(n))

    def step(carry, key):
        x_d, x_c, x_u = carry
        r_d = mu_d * x_d
        r_c = mu_c * (x_c > 0)
        r_u = mu_u * x_u
        rates = jnp.concatenate([r_d, r_c, r_u])
        total = jnp.sum(rates)
        k1, k2, k3 = jax.random.split(key, 3)
        dt = jax.random.exponential(k1) / total
        occ_pre = jnp.concatenate([x_d, x_c, x_u])
        ev = jax.random.categorical(k2, jnp.log(jnp.maximum(rates, 1e-300)))
        i = ev % n
        kind = ev // n
        onei = jax.nn.one_hot(i, n)
        # downlink completion: d -> c ; compute: c -> u ; uplink: u -> d_j
        x_d = x_d - onei * (kind == 0)
        x_c = x_c + onei * (kind == 0) - onei * (kind == 1)
        x_u = x_u + onei * (kind == 1) - onei * (kind == 2)
        j = jax.random.categorical(k3, jnp.log(p))
        x_d = x_d + jax.nn.one_hot(j, n) * (kind == 2)
        lam_inst = jnp.sum(r_u)
        return (x_d, x_c, x_u), (dt, dt * lam_inst, dt * occ_pre)

    keys = jax.random.split(key, steps)
    _, (dts, lam_w, occ_w) = jax.lax.scan(step, state0, keys)
    # discard first third as warmup
    w = steps // 3
    T = jnp.sum(dts[w:])
    lam = jnp.sum(lam_w[w:]) / T
    occ = jnp.sum(occ_w[w:], axis=0) / T
    return float(lam), np.asarray(occ)
