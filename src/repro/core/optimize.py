"""Gradient-based optimization of routing and concurrency (Sections 5.3.2,
6.4, Appendices B.2 / J).

The routing vector lives on the simplex via the softmax reparameterization of
Appendix B.2 (``p = softmax(theta)``); objectives are minimized with Adam.
Gradients come from ``jax.grad`` through the log-space Buzen pipeline — tested
to agree with the paper's closed-form expressions (Theorem 2 Eq. 4,
Prop. 4 Eq. 12).

Concurrency ``m`` is discrete.  Two search modes are provided:

  * :func:`sequential_concurrency_search` — the paper's warm-started
    sequential search (Section 5.3.2): iterate m = start, start+1, ...,
    re-optimizing ``p`` from the previous optimum, stopping once the
    objective stops improving (with optional patience).  One jit compile
    *per candidate m* — kept as the reference implementation.
  * :func:`batched_concurrency_sweep` — the batched engine: ONE jitted
    Adam ``lax.scan`` optimizes routing for *all* candidate concurrencies
    (and optionally a batch of objective contexts, e.g. Pareto weights
    ``rho``) simultaneously.  Each scan step evaluates the padded log-space
    Buzen DP for the whole ``[B, n]`` routing batch
    (``repro.core.batched``), so the discrete search reduces to an argmin
    over the precomputed ``(p*, m)`` surface with zero per-``m``
    recompilation.
  * :func:`pruned_concurrency_sweep` — coarse-to-fine wrapper over the
    batched engine for paper-scale grids (n=100 / m_max=132), where the
    full-grid sweep's B-fold arithmetic starts to outweigh its
    zero-recompile win: a strided coarse pass plus a warm-started
    refinement around its winner evaluates ~2 sqrt(B) rows instead of B.

``time_optimal`` / ``joint_optimal`` use the batched engine by default
(``search="pruned"`` selects the coarse-to-fine variant,
``search="sequential"`` restores the legacy path).
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from . import numerics  # noqa: F401
from .buzen import ClassParams, NetworkParams, log_normalizing_constants
from .complexity import LearningConstants, round_complexity, wallclock_time
from .energy import PowerProfile, energy_complexity, joint_objective
from .jackson import throughput


@dataclasses.dataclass
class OptResult:
    p: jax.Array
    m: int
    value: float
    history: list


@dataclasses.dataclass
class SweepResult:
    """Full ``(p, m)`` surface from one batched sweep.

    ``p[b]`` is the optimized routing for concurrency ``m_grid[b]`` (and
    context ``ctx[b]`` if given); ``values[b]`` the final objective there.
    ``best`` is the argmin row repackaged as an :class:`OptResult` whose
    ``history`` is the ``(m, value)`` trace across the grid.
    """

    p: jax.Array          # [B, n]
    m_grid: np.ndarray    # [B]
    values: np.ndarray    # [B]
    best: OptResult


def _adam_minimize(loss_fn: Callable, theta0: jax.Array, steps: int, lr: float):
    """Plain Adam on unconstrained logits; jitted scan."""
    b1, b2, eps = 0.9, 0.999, 1e-8

    @jax.jit
    def run(theta0):
        def step(carry, t):
            theta, mu, nu = carry
            val, g = jax.value_and_grad(loss_fn)(theta)
            mu = b1 * mu + (1 - b1) * g
            nu = b2 * nu + (1 - b2) * g * g
            mu_hat = mu / (1 - b1 ** (t + 1.0))
            nu_hat = nu / (1 - b2 ** (t + 1.0))
            theta = theta - lr * mu_hat / (jnp.sqrt(nu_hat) + eps)
            return (theta, mu, nu), val

        init = (theta0, jnp.zeros_like(theta0), jnp.zeros_like(theta0))
        (theta, _, _), vals = jax.lax.scan(step, init, jnp.arange(steps, dtype=jnp.float64))
        return theta, vals

    return run(theta0)


def optimize_routing(
    objective: Callable[[jax.Array, int], jax.Array],
    n: int,
    m: int,
    *,
    steps: int = 400,
    lr: float = 0.05,
    p_init: Optional[jax.Array] = None,
) -> OptResult:
    """Minimize ``objective(p, m)`` over the simplex with softmax-Adam."""
    p0 = jnp.full((n,), 1.0 / n) if p_init is None else p_init
    theta0 = jnp.log(jnp.clip(p0, 1e-12))

    def loss(theta):
        p = jax.nn.softmax(theta)
        return objective(p, m)

    theta, vals = _adam_minimize(loss, theta0, steps, lr)
    p = jax.nn.softmax(theta)
    return OptResult(p=p, m=m, value=float(objective(p, m)), history=list(map(float, vals)))


def _sharded_rows(solve, theta0, m_grid, ctx, B: int):
    """Run a row-local solver with its row axis split over local devices.

    Rows pad to a device multiple by repeating the last row (sliced back
    off the result).  ``solve(theta_rows, m_rows, ctx_rows)`` must be
    row-local — no cross-row reductions reach the outputs — so each shard
    computes exactly what it would single-device and the concatenated
    result is **bitwise** equal to the unsharded call.
    """
    from jax.sharding import PartitionSpec

    from ..compat import make_mesh, shard_map

    ndev = len(jax.devices())
    Bp = -(-B // ndev) * ndev

    def pad_rows(x):
        if x is None or Bp == B:
            return x
        reps = jnp.broadcast_to(x[-1:], (Bp - B,) + x.shape[1:])
        return jnp.concatenate([x, reps], axis=0)

    mesh = make_mesh((ndev,), ("lanes",))
    spec = PartitionSpec("lanes")
    if ctx is None:
        fn = shard_map(lambda th, mm: solve(th, mm, None), mesh,
                       in_specs=(spec, spec), out_specs=(spec, spec))
        ps, vals = jax.jit(fn)(pad_rows(theta0), pad_rows(m_grid))
    else:
        fn = shard_map(solve, mesh, in_specs=(spec, spec, spec),
                       out_specs=(spec, spec))
        ps, vals = jax.jit(fn)(pad_rows(theta0), pad_rows(m_grid),
                               pad_rows(jnp.asarray(ctx)))
    return ps[:B], vals[:B]


def batched_concurrency_sweep(
    objective: Callable,
    params: NetworkParams,
    *,
    m_grid,
    ctx=None,
    steps: int = 400,
    lr: float = 0.05,
    p_init: Optional[jax.Array] = None,
    m_max: Optional[int] = None,
    backend: Optional[str] = None,
    shard: bool = False,
) -> SweepResult:
    """Optimize routing for every concurrency candidate in ONE jitted sweep.

    ``objective`` follows the padded protocol of ``repro.core.batched``:
    ``obj(p, m, logZ)`` (or ``obj(p, m, logZ, ctx_row)`` when ``ctx`` is
    given) with ``m`` traced and ``logZ`` the precomputed ``[m_max + 1]``
    log-constant row for ``p``.  The engine stacks ``B = len(m_grid)``
    softmax logits, computes the batched Buzen DP once per Adam step (one
    ``[B, m_max+1]`` evaluation, Pallas or jnp backend), and runs a single
    ``lax.scan`` whose summed loss decouples row-wise — elementwise Adam on
    a block-diagonal problem is exactly ``B`` independent Adam runs, minus
    the ``B`` recompiles.

    ``ctx`` optionally batches an extra per-row objective input (e.g. the
    Pareto weight ``rho``), so one sweep can also span strategy variants.

    ``params`` may be a :class:`ClassParams`: rows are then per-member
    routing over classes (the O(C) negative-binomial DP replaces the O(n)
    one), the simplex constraint ``sum_c count_c p_c = 1`` is enforced by a
    softmax over class *masses*, and padded (count-0) classes are masked
    out of the logits.

    ``shard=True`` splits the ``B`` rows across all local devices with
    ``shard_map`` (rows pad to a device multiple by repeating the last
    row).  Rows never interact — the Buzen DP, the objective and Adam are
    all row-local — so the sharded sweep is **bitwise** equal to the
    single-device one, at ``1/num_devices`` the per-device row count.
    """
    from .batched import (batch_class_log_normalizing_constants,
                          batch_log_normalizing_constants)

    m_grid = jnp.asarray(m_grid, dtype=jnp.int64)
    B = int(m_grid.shape[0])
    is_classes = isinstance(params, ClassParams)
    if is_classes:
        n = params.C
        cmask = np.asarray(params.count) > 0
        cnt_safe = jnp.where(jnp.asarray(cmask),
                             params.count.astype(jnp.float64), 1.0)
        n_total = float(np.asarray(params.count).sum())
    else:
        n = params.n
    m_top = int(jnp.max(m_grid))
    m_pad = m_top if m_max is None else m_max
    if m_pad < m_top:
        # jit'd gathers clamp out-of-range indices silently — fail loudly
        # instead of returning plausible-but-truncated objective values
        raise ValueError(
            f"m_max={m_pad} must cover max(m_grid)={m_top}; the padded "
            "objective must be built with the same m_max")
    obj_pad = getattr(objective, "m_max", None)
    if obj_pad is not None and obj_pad != m_pad:
        raise ValueError(
            f"objective was built with m_max={obj_pad} but this sweep pads "
            f"logZ to m_max={m_pad}; the paddings must match")

    if is_classes:
        # logits parameterize class masses q (sum 1); members share
        # p = q / count, and padded classes are pinned to -inf mass
        p0 = (jnp.full((n,), 1.0 / n_total) if p_init is None
              else jnp.asarray(p_init))
        q0 = params.count.astype(jnp.float64) * p0
        theta0 = jnp.log(jnp.clip(q0, 1e-12))
    else:
        p0 = (jnp.full((n,), 1.0 / n) if p_init is None
              else jnp.asarray(p_init))
        theta0 = jnp.log(jnp.clip(p0, 1e-12))
    if theta0.ndim == 1:
        theta0 = jnp.broadcast_to(theta0, (B, n))

    def to_p(thetas):
        if is_classes:
            th = jnp.where(jnp.asarray(cmask)[None, :], thetas, -jnp.inf)
            return jax.nn.softmax(th, axis=-1) / cnt_safe[None, :]
        return jax.nn.softmax(thetas, axis=-1)

    def row_values(thetas, m_rows, ctx_rows):
        ps = to_p(thetas)
        if is_classes:
            logZ = batch_class_log_normalizing_constants(params, ps, m_pad,
                                                         backend=backend)
        else:
            logZ = batch_log_normalizing_constants(params, ps, m_pad,
                                                   backend=backend)
        if ctx_rows is None:
            vals = jax.vmap(objective)(ps, m_rows, logZ)
        else:
            vals = jax.vmap(objective)(ps, m_rows, logZ, ctx_rows)
        return ps, vals

    def solve(theta0_rows, m_rows, ctx_rows):
        def loss(thetas):
            return jnp.sum(row_values(thetas, m_rows, ctx_rows)[1])

        theta, _ = _adam_minimize(loss, theta0_rows, steps, lr)
        return row_values(theta, m_rows, ctx_rows)

    # both paths jit the SAME solve (scan + final evaluation as one
    # program): jit(solve) == jit(shard_map(solve)) bitwise, whereas an
    # eager final evaluation fuses differently in the last bit
    if shard:
        ps, vals = _sharded_rows(solve, theta0, m_grid, ctx, B)
    elif ctx is None:
        ps, vals = jax.jit(lambda th, mm: solve(th, mm, None))(theta0,
                                                               m_grid)
    else:
        ps, vals = jax.jit(solve)(theta0, m_grid, jnp.asarray(ctx))

    m_np = np.asarray(m_grid)
    vals_np = np.asarray(vals)
    b = int(np.argmin(vals_np))
    best = OptResult(p=ps[b], m=int(m_np[b]), value=float(vals_np[b]),
                     history=[(int(m), float(v))
                              for m, v in zip(m_np, vals_np)])
    return SweepResult(p=ps, m_grid=m_np, values=vals_np, best=best)


def pruned_concurrency_sweep(
    objective: Callable,
    params: NetworkParams,
    *,
    m_grid,
    ctx=None,
    coarse_stride: Optional[int] = None,
    min_full: int = 8,
    **kw,
) -> SweepResult:
    """Coarse-to-fine batched sweep: evaluate a strided subsample of the
    ``m`` grid first, then refine only between the coarse neighbours of the
    winner (warm-started from its routing).

    At paper scale the full-grid sweep trades per-``m`` recompiles for
    ``B``-fold more arithmetic per Adam step; pruning keeps the
    zero-recompile property (two compiles total: one coarse, one refine
    batch shape) while cutting the per-step batch to roughly
    ``2 sqrt(B)`` rows.  It assumes the optimized objective is well-behaved
    over ``m`` (unimodal up to the coarse stride) — the regime of the
    paper's wall-clock/joint objectives (Figs. 2/8) — and is cross-checked
    against the full sweep on small grids in
    ``tests/test_scenario.py``.  Grids of at most ``min_full`` points run
    the full sweep directly.

    ``ctx`` (per-row objective context) is subset alongside ``m_grid``;
    pruning treats the grid as a single monotone ``m`` axis, so product
    grids (e.g. ``pareto_sweep``'s rho-major layout) should use the full
    sweep per context instead.
    """
    m_np = np.asarray(m_grid, dtype=np.int64)
    if m_np.ndim != 1 or m_np.size == 0:
        raise ValueError(f"m_grid must be a non-empty 1-D grid, got shape "
                         f"{m_np.shape}")
    if not (np.diff(m_np) > 0).all():
        raise ValueError("pruned search needs a strictly increasing m_grid")
    B = int(m_np.size)
    # pin the logZ padding for every pass: the refine window's max m is
    # smaller than the full grid's, and an objective built for the full
    # grid would otherwise trip the sweep-side padding guard mid-search
    if kw.get("m_max") is None:
        kw["m_max"] = getattr(objective, "m_max", None) or int(m_np[-1])
    if B <= max(int(min_full), 1):
        return batched_concurrency_sweep(objective, params, m_grid=m_np,
                                         ctx=ctx, **kw)

    ctx_np = None if ctx is None else np.asarray(ctx)
    stride = (max(2, int(round(np.sqrt(B)))) if coarse_stride is None
              else max(2, int(coarse_stride)))
    coarse = np.unique(np.append(np.arange(0, B, stride), B - 1))

    def sub(idx):
        return (m_np[idx],
                None if ctx_np is None else jnp.asarray(ctx_np[idx]))

    mg, cx = sub(coarse)
    first = batched_concurrency_sweep(objective, params, m_grid=mg, ctx=cx,
                                      **kw)
    k = int(np.argmin(first.values))
    lo = int(coarse[max(k - 1, 0)])
    hi = int(coarse[min(k + 1, len(coarse) - 1)])
    refine = np.setdiff1d(np.arange(lo, hi + 1), coarse)

    ms = [first.m_grid]
    vals = [first.values]
    ps = [np.asarray(first.p)]
    if refine.size:
        mg2, cx2 = sub(refine)
        kw2 = dict(kw)
        kw2["p_init"] = first.p[k]  # warm start from the coarse winner
        second = batched_concurrency_sweep(objective, params, m_grid=mg2,
                                           ctx=cx2, **kw2)
        ms.append(second.m_grid)
        vals.append(second.values)
        ps.append(np.asarray(second.p))

    m_all = np.concatenate(ms)
    order = np.argsort(m_all)
    m_all = m_all[order]
    v_all = np.concatenate(vals)[order]
    p_all = np.concatenate(ps, axis=0)[order]
    b = int(np.argmin(v_all))
    best = OptResult(p=jnp.asarray(p_all[b]), m=int(m_all[b]),
                     value=float(v_all[b]),
                     history=[(int(m), float(v))
                              for m, v in zip(m_all, v_all)])
    return SweepResult(p=jnp.asarray(p_all), m_grid=m_all, values=v_all,
                       best=best)


def pareto_sweep(params: NetworkParams, consts, power, rhos, tau_star,
                 e_star, *, m_max: int, **kw
                 ) -> tuple[SweepResult, list[OptResult]]:
    """Trace the Eq.-18 time-energy frontier in ONE batched sweep.

    Optimizes the joint objective over the full ``rhos x (1..m_max)``
    product grid (``rho`` rides the ctx batch) and argmins per rho.
    Returns the raw :class:`SweepResult` (rows ordered rho-major, matching
    ``np.tile(m_cands, len(rhos))``) plus one :class:`OptResult` per rho
    whose ``history`` is that rho's ``(m, value)`` slice.
    """
    from .batched import make_joint_objective_padded

    m_cands = np.arange(1, m_max + 1)
    mm = jnp.asarray(np.tile(m_cands, len(rhos)))
    rr = jnp.asarray(np.repeat(np.asarray(rhos, dtype=np.float64),
                               len(m_cands)))
    sweep = batched_concurrency_sweep(
        make_joint_objective_padded(params, consts, power, tau_star, e_star,
                                    m_max), params,
        m_grid=mm, ctx=rr, m_max=m_max, **kw)
    vals = sweep.values.reshape(len(rhos), len(m_cands))
    per_rho = []
    for r_i in range(len(rhos)):
        b = r_i * len(m_cands) + int(np.argmin(vals[r_i]))
        per_rho.append(OptResult(
            p=sweep.p[b], m=int(sweep.m_grid[b]),
            value=float(sweep.values[b]),
            history=[(int(m), float(v)) for m, v in zip(m_cands, vals[r_i])]))
    return sweep, per_rho


def sequential_concurrency_search(
    objective: Callable[[jax.Array, int], jax.Array],
    n: int,
    *,
    m_start: int = 1,
    m_max: int = 256,
    steps: int = 400,
    lr: float = 0.05,
    patience: int = 2,
    p_init: Optional[jax.Array] = None,
) -> OptResult:
    """Sequential (m, p) optimization with warm starts (Section 5.3.2)."""
    best: Optional[OptResult] = None
    stale = 0
    p_warm = p_init
    trace = []
    for m in range(max(m_start, 1), m_max + 1):
        res = optimize_routing(objective, n, m, steps=steps, lr=lr, p_init=p_warm)
        trace.append((m, res.value))
        p_warm = res.p
        if best is None or res.value < best.value:
            best = res
            stale = 0
        else:
            stale += 1
            if stale >= patience:
                break
    best.history = trace
    return best


# ---------------------------------------------------------------------------
# canned objectives / strategies of Section 5.3
# ---------------------------------------------------------------------------

def _with_p(params: NetworkParams, p: jax.Array) -> NetworkParams:
    return params._replace(p=p)


def make_round_objective(params: NetworkParams, consts: LearningConstants):
    """Minimize K_eps — the 'Round-Optimized' strategy."""
    def obj(p, m):
        return round_complexity(_with_p(params, p), m, consts)
    return obj


def make_throughput_objective(params: NetworkParams):
    """Maximize lambda — the 'Max-Throughput' strategy (negated)."""
    def obj(p, m):
        return -throughput(_with_p(params, p), m)
    return obj


def make_time_objective(params: NetworkParams, consts: LearningConstants):
    """Minimize E0[tau_eps] — the paper's proposed strategy."""
    def obj(p, m):
        return wallclock_time(_with_p(params, p), m, consts)
    return obj


def make_energy_objective(params: NetworkParams, consts: LearningConstants,
                          power: PowerProfile):
    def obj(p, m):
        return energy_complexity(_with_p(params, p), m, consts, power)
    return obj


def make_joint_objective(params: NetworkParams, consts: LearningConstants,
                         power: PowerProfile, rho: float,
                         tau_star: float, e_star: float):
    """Eq. (18) normalized scalarization."""
    def obj(p, m):
        return joint_objective(_with_p(params, p), m, consts, power, rho,
                               tau_star, e_star)
    return obj


def time_optimal(params: NetworkParams, consts: LearningConstants,
                 m_max: Optional[int] = None, *, search: str = "batched",
                 **kw) -> OptResult:
    """(p*_tau, m*_tau): jointly time-optimal routing and concurrency.

    ``search``: ``"batched"`` (full-grid one-compile sweep, default),
    ``"pruned"`` (coarse-to-fine batched sweep — the paper-scale variant),
    or ``"sequential"`` (the paper's warm-started reference loop).
    """
    m_max = m_max or params.n + 32
    if search in ("batched", "pruned"):
        from .batched import make_time_objective_padded

        kw.pop("patience", None)  # full grid — no early stop to tune
        engine = (batched_concurrency_sweep if search == "batched"
                  else pruned_concurrency_sweep)
        res = engine(
            make_time_objective_padded(params, consts, m_max), params,
            m_grid=jnp.arange(2, m_max + 1), m_max=m_max, **kw)
        return res.best
    if search != "sequential":
        raise ValueError(f"unknown search mode: {search!r}; expected "
                         "'batched', 'pruned' or 'sequential'")
    return sequential_concurrency_search(
        make_time_objective(params, consts), params.n, m_start=2, m_max=m_max, **kw)


def time_optimal_classes(classes: ClassParams, consts: LearningConstants,
                         m_max: int, *, search: str = "batched",
                         **kw) -> OptResult:
    """Class-space ``time_optimal``: O(C) per Adam step instead of O(n).

    ``m_max`` is explicit (the per-client default ``n + 32`` would be
    absurd at ``n = 10^6``; concurrency is a deployment budget there).
    Returns per-member routing ``p`` (length ``C``) under the mass
    constraint ``sum_c count_c p_c = 1``.
    """
    from .batched import make_time_objective_classes

    if search not in ("batched", "pruned"):
        raise ValueError(f"unknown search mode: {search!r}; expected "
                         "'batched' or 'pruned'")
    engine = (batched_concurrency_sweep if search == "batched"
              else pruned_concurrency_sweep)
    res = engine(
        make_time_objective_classes(classes, consts, m_max), classes,
        m_grid=jnp.arange(2, m_max + 1), m_max=m_max, **kw)
    return res.best


def round_optimal(params: NetworkParams, consts: LearningConstants, m: int,
                  **kw) -> OptResult:
    return optimize_routing(make_round_objective(params, consts), params.n, m, **kw)


def max_throughput(params: NetworkParams, m: int, **kw) -> OptResult:
    return optimize_routing(make_throughput_objective(params), params.n, m, **kw)


def joint_optimal(params: NetworkParams, consts: LearningConstants,
                  power: PowerProfile, rho: float, tau_star: float,
                  e_star: float, m_max: Optional[int] = None, *,
                  search: str = "batched", **kw) -> OptResult:
    m_max = m_max or params.n + 32
    if search in ("batched", "pruned"):
        from .batched import make_joint_objective_padded

        kw.pop("patience", None)
        engine = (batched_concurrency_sweep if search == "batched"
                  else pruned_concurrency_sweep)
        m_grid = jnp.arange(1, m_max + 1)
        res = engine(
            make_joint_objective_padded(params, consts, power, tau_star,
                                        e_star, m_max), params,
            m_grid=m_grid, ctx=jnp.full(m_grid.shape, rho), m_max=m_max,
            **kw)
        return res.best
    if search != "sequential":
        raise ValueError(f"unknown search mode: {search!r}; expected "
                         "'batched', 'pruned' or 'sequential'")
    return sequential_concurrency_search(
        make_joint_objective(params, consts, power, rho, tau_star, e_star),
        params.n, m_start=1, m_max=m_max, **kw)
