"""Gradient-based optimization of routing and concurrency (Sections 5.3.2,
6.4, Appendices B.2 / J).

The routing vector lives on the simplex via the softmax reparameterization of
Appendix B.2 (``p = softmax(theta)``); objectives are minimized with Adam.
Gradients come from ``jax.grad`` through the log-space Buzen pipeline — tested
to agree with the paper's closed-form expressions (Theorem 2 Eq. 4,
Prop. 4 Eq. 12).

Concurrency ``m`` is discrete and handled by the paper's sequential search
with warm-started routing (Section 5.3.2): iterate m = start, start+1, ...,
re-optimizing ``p`` from the previous optimum, and stop once the objective
stops improving (with optional patience).
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Callable, Optional

import jax
import jax.numpy as jnp

from . import numerics  # noqa: F401
from .buzen import NetworkParams, log_normalizing_constants
from .complexity import LearningConstants, round_complexity, wallclock_time
from .energy import PowerProfile, energy_complexity, joint_objective
from .jackson import throughput


@dataclasses.dataclass
class OptResult:
    p: jax.Array
    m: int
    value: float
    history: list


def _adam_minimize(loss_fn: Callable, theta0: jax.Array, steps: int, lr: float):
    """Plain Adam on unconstrained logits; jitted scan."""
    b1, b2, eps = 0.9, 0.999, 1e-8

    @jax.jit
    def run(theta0):
        def step(carry, t):
            theta, mu, nu = carry
            val, g = jax.value_and_grad(loss_fn)(theta)
            mu = b1 * mu + (1 - b1) * g
            nu = b2 * nu + (1 - b2) * g * g
            mu_hat = mu / (1 - b1 ** (t + 1.0))
            nu_hat = nu / (1 - b2 ** (t + 1.0))
            theta = theta - lr * mu_hat / (jnp.sqrt(nu_hat) + eps)
            return (theta, mu, nu), val

        init = (theta0, jnp.zeros_like(theta0), jnp.zeros_like(theta0))
        (theta, _, _), vals = jax.lax.scan(step, init, jnp.arange(steps, dtype=jnp.float64))
        return theta, vals

    return run(theta0)


def optimize_routing(
    objective: Callable[[jax.Array, int], jax.Array],
    n: int,
    m: int,
    *,
    steps: int = 400,
    lr: float = 0.05,
    p_init: Optional[jax.Array] = None,
) -> OptResult:
    """Minimize ``objective(p, m)`` over the simplex with softmax-Adam."""
    p0 = jnp.full((n,), 1.0 / n) if p_init is None else p_init
    theta0 = jnp.log(jnp.clip(p0, 1e-12))

    def loss(theta):
        p = jax.nn.softmax(theta)
        return objective(p, m)

    theta, vals = _adam_minimize(loss, theta0, steps, lr)
    p = jax.nn.softmax(theta)
    return OptResult(p=p, m=m, value=float(objective(p, m)), history=list(map(float, vals)))


def sequential_concurrency_search(
    objective: Callable[[jax.Array, int], jax.Array],
    n: int,
    *,
    m_start: int = 1,
    m_max: int = 256,
    steps: int = 400,
    lr: float = 0.05,
    patience: int = 2,
    p_init: Optional[jax.Array] = None,
) -> OptResult:
    """Sequential (m, p) optimization with warm starts (Section 5.3.2)."""
    best: Optional[OptResult] = None
    stale = 0
    p_warm = p_init
    trace = []
    for m in range(max(m_start, 1), m_max + 1):
        res = optimize_routing(objective, n, m, steps=steps, lr=lr, p_init=p_warm)
        trace.append((m, res.value))
        p_warm = res.p
        if best is None or res.value < best.value:
            best = res
            stale = 0
        else:
            stale += 1
            if stale >= patience:
                break
    best.history = trace
    return best


# ---------------------------------------------------------------------------
# canned objectives / strategies of Section 5.3
# ---------------------------------------------------------------------------

def _with_p(params: NetworkParams, p: jax.Array) -> NetworkParams:
    return params._replace(p=p)


def make_round_objective(params: NetworkParams, consts: LearningConstants):
    """Minimize K_eps — the 'Round-Optimized' strategy."""
    def obj(p, m):
        return round_complexity(_with_p(params, p), m, consts)
    return obj


def make_throughput_objective(params: NetworkParams):
    """Maximize lambda — the 'Max-Throughput' strategy (negated)."""
    def obj(p, m):
        return -throughput(_with_p(params, p), m)
    return obj


def make_time_objective(params: NetworkParams, consts: LearningConstants):
    """Minimize E0[tau_eps] — the paper's proposed strategy."""
    def obj(p, m):
        return wallclock_time(_with_p(params, p), m, consts)
    return obj


def make_energy_objective(params: NetworkParams, consts: LearningConstants,
                          power: PowerProfile):
    def obj(p, m):
        return energy_complexity(_with_p(params, p), m, consts, power)
    return obj


def make_joint_objective(params: NetworkParams, consts: LearningConstants,
                         power: PowerProfile, rho: float,
                         tau_star: float, e_star: float):
    """Eq. (18) normalized scalarization."""
    def obj(p, m):
        return joint_objective(_with_p(params, p), m, consts, power, rho,
                               tau_star, e_star)
    return obj


def time_optimal(params: NetworkParams, consts: LearningConstants,
                 m_max: Optional[int] = None, **kw) -> OptResult:
    """(p*_tau, m*_tau): jointly time-optimal routing and concurrency."""
    m_max = m_max or params.n + 32
    return sequential_concurrency_search(
        make_time_objective(params, consts), params.n, m_start=2, m_max=m_max, **kw)


def round_optimal(params: NetworkParams, consts: LearningConstants, m: int,
                  **kw) -> OptResult:
    return optimize_routing(make_round_objective(params, consts), params.n, m, **kw)


def max_throughput(params: NetworkParams, m: int, **kw) -> OptResult:
    return optimize_routing(make_throughput_objective(params), params.n, m, **kw)


def joint_optimal(params: NetworkParams, consts: LearningConstants,
                  power: PowerProfile, rho: float, tau_star: float,
                  e_star: float, m_max: Optional[int] = None, **kw) -> OptResult:
    m_max = m_max or params.n + 32
    return sequential_concurrency_search(
        make_joint_objective(params, consts, power, rho, tau_star, e_star),
        params.n, m_start=1, m_max=m_max, **kw)
