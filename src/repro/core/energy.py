"""Energy complexity of Generalized AsyncSGD (Section 6 / Section 7.5).

Implements:
  * the phase-dependent power model (Eq. 13/14) with cubic DVFS computation
    power ``P_comp = kappa * (mu_c)^3`` (Section 6.5.1);
  * Proposition 5 — ``E0[E_eps] = K_eps(p, m) * sum_i p_i E_i`` with the
    per-task energy cost ``E_i = P_c/mu_c + P_u/mu_u + P_d/mu_d``;
  * Proposition 9 — CS-buffered variant with the extra ``P_cs / mu_cs`` term;
  * the closed-form energy-optimal routing (Eq. 16 / 28) and minimum energy
    (Eq. 17 / 29) via Cauchy–Schwarz;
  * the rho-scalarized joint time–energy objective (Eq. 18).
"""
from __future__ import annotations
# contract: padded-n — reductions here are on the bitwise padding contract

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from . import numerics  # noqa: F401
from .buzen import NetworkParams, log_normalizing_constants
from .complexity import LearningConstants, round_complexity, wallclock_time
from .numerics import seqsum


class PowerProfile(NamedTuple):
    """Per-client phase powers (Section 6.1)."""

    P_c: jax.Array  # [n] computation power
    P_u: jax.Array  # [n] uplink transmission power
    P_d: jax.Array  # [n] downlink reception power
    P_cs: Optional[jax.Array] = None  # scalar CS processing power (Section 7.5)

    @staticmethod
    def from_dvfs(kappa: jax.Array, mu_c: jax.Array, P_u: jax.Array,
                  P_d: jax.Array, P_cs=None) -> "PowerProfile":
        """Cubic DVFS law: ``P_comp = kappa * mu_c**3`` (Section 6.5.1)."""
        return PowerProfile(P_c=kappa * mu_c**3, P_u=P_u, P_d=P_d, P_cs=P_cs)


def per_task_energy(params: NetworkParams, power: PowerProfile) -> jax.Array:
    """``E_i = P_c/mu_c + P_u/mu_u + P_d/mu_d`` — mean energy per task."""
    return (power.P_c / params.mu_c + power.P_u / params.mu_u
            + power.P_d / params.mu_d)


def energy_per_round(params: NetworkParams, power: PowerProfile) -> jax.Array:
    """``E[P(0)] / lambda`` — mean energy per round (Prop. 5 / Prop. 9).

    Client-axis sums are sequential (``numerics.seqsum``) so padded rows
    (zero routing, zero power) are bitwise invisible — part of the
    traced-``n`` contract.
    """
    e = seqsum(params.p / seqsum(params.p) * per_task_energy(params, power))
    if power.P_cs is not None:
        if params.mu_cs is None:
            raise ValueError("P_cs given but params.mu_cs is None")
        e = e + power.P_cs / params.mu_cs
    return e


def energy_per_round_classes(classes, power: PowerProfile) -> jax.Array:
    """Class-space :func:`energy_per_round`: O(C) with ``power`` holding
    per-class arrays.

    ``sum_i p_i E_i / sum_i p_i`` over clients groups into
    ``sum_c count_c p_c E_c / sum_c count_c p_c`` — class masses weight the
    per-member task energies; padded classes (count 0) add exact zeros to
    both sequential sums.
    """
    e_member = (power.P_c / classes.mu_c + power.P_u / classes.mu_u
                + power.P_d / classes.mu_d)
    mass = classes.mass
    e = seqsum(mass / seqsum(mass) * e_member)
    if power.P_cs is not None:
        if classes.mu_cs is None:
            raise ValueError("P_cs given but classes.mu_cs is None")
        e = e + power.P_cs / classes.mu_cs
    return e


def energy_complexity(params: NetworkParams, m: int, consts: LearningConstants,
                      power: PowerProfile,
                      logZ: jax.Array | None = None) -> jax.Array:
    """``E0[E_eps] = K_eps(p, m) * energy_per_round`` — Prop. 5 / Prop. 9."""
    if logZ is None:
        logZ = log_normalizing_constants(params, m)
    return round_complexity(params, m, consts, logZ) * energy_per_round(params, power)


def energy_optimal_routing(params: NetworkParams, power: PowerProfile) -> jax.Array:
    """Closed-form minimizer at ``m = 1`` (Eq. 16 / Eq. 28)."""
    e = per_task_energy(params, power)
    if power.P_cs is not None:
        if params.mu_cs is None:
            raise ValueError("P_cs given but params.mu_cs is None")
        e = e + power.P_cs / params.mu_cs
    w = 1.0 / jnp.sqrt(e)
    # sequential client-axis sum: p*_E computed on a padded network must
    # equal the unpadded result bitwise (padded rows have w finite but the
    # caller masks them; the normalizer itself must not reassociate)
    return w / seqsum(w)


def minimal_energy(params: NetworkParams, consts: LearningConstants,
                   power: PowerProfile) -> jax.Array:
    """``E*`` — Eq. (17) / Eq. (29): energy at ``(p*_E, m = 1)``."""
    n = params.n
    e = per_task_energy(params, power)
    if power.P_cs is not None:
        e = e + power.P_cs / params.mu_cs
    pref = 24.0 * consts.L * consts.delta / (n**2 * consts.eps)
    return pref * (4.0 + consts.B / consts.eps) * seqsum(jnp.sqrt(e)) ** 2


def joint_objective(params: NetworkParams, m: int, consts: LearningConstants,
                    power: PowerProfile, rho: float,
                    tau_star: jax.Array, e_star: jax.Array,
                    logZ: jax.Array | None = None) -> jax.Array:
    """Normalized rho-scalarization (Eq. 18)."""
    if logZ is None:
        logZ = log_normalizing_constants(params, m)
    tau = wallclock_time(params, m, consts, logZ)
    en = energy_complexity(params, m, consts, power, logZ)
    return rho * en / e_star + (1.0 - rho) * tau / tau_star
