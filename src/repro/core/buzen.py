"""Buzen's recursive algorithm for closed-network normalization constants.

Implements Proposition 15 (client-only network of Section 2.6) and
Proposition 19 (network with a CS-side single-server queue, Section 7) of the
paper, in log space.

Network structure (Section 2.6):
  * ``n`` single-server FIFO queues ``c_i`` with service rate ``mu_c[i]`` and
    visit ratio ``p[i]``  ->  load ``rho[i] = p[i] / mu_c[i]``;
  * ``2n`` infinite-server queues (downlink ``d_i``, uplink ``u_i``) with
    loads ``p[i]/mu_d[i]`` and ``p[i]/mu_u[i]``.

With the CS buffer (Section 7) there is one extra single-server queue with
load ``1/mu_cs`` (every task visits the CS once per cycle; the multinomial
class structure of Eq. (20) sums out to a plain geometric factor, see
``DESIGN.md``).

Two evaluation strategies, tested to agree:

  * ``method="literal"`` — the station-by-station recursion of Prop. 15:
    each single-server station convolves the running constants with a
    geometric series, each IS station with a Poisson series.  O(n m^2).
  * ``method="aggregate"`` — beyond-paper fast path: all 2n IS stations
    merge analytically into a single Poisson factor with aggregate load
    ``gamma_tot = sum_i p_i (1/mu_d[i] + 1/mu_u[i])``, because product-form
    IS stations only enter Z through the total-load exponential series.
    O(n m + m^2).

All functions return ``logZ`` arrays of shape ``[m_max + 1]`` with
``logZ[k] = log Z_{n,k}``; ``Z_{n,0} = 1``.

Backends: the DP can also run on the Pallas TPU kernel
(``repro.kernels.buzen``).  Select it per call with ``backend="pallas"``,
process-wide with :func:`set_backend` (or ``REPRO_BUZEN_BACKEND=pallas``).
The kernel computes the forward pass in float32 (compiled on TPU,
interpreted elsewhere) and differentiates through the float64 reference, so
it is usable inside the routing optimizer; the default remains ``"jnp"``
because the analytic identities in the test-suite hold to 1e-12 only in
float64.
"""
from __future__ import annotations
# contract: padded-n — reductions here are on the bitwise padding contract

import os
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp
from jax.scipy.special import gammaln, logsumexp

from . import numerics  # noqa: F401  (enables x64)
from .numerics import NEG_INF, seqsum

_BACKENDS = ("jnp", "pallas")
# contract: allow(env-read): import-time default only — set_backend() overrides it at runtime, nothing caches the value
_backend = os.environ.get("REPRO_BUZEN_BACKEND", "jnp")


def set_backend(name: str) -> None:
    """Set the process-wide default Buzen backend (``"jnp"``/``"pallas"``)."""
    global _backend
    if name not in _BACKENDS:
        raise ValueError(f"unknown buzen backend: {name!r}")
    _backend = name


def get_backend() -> str:
    return _backend


class NetworkParams(NamedTuple):
    """Rates of the closed queueing network (Section 2.6 / 7.1).

    Padded-``n`` convention: arrays may be padded to a static ``n_max``
    (zero routing mass, unit rates beyond the real population) with
    ``n_active`` holding the traced count of *real* clients — see
    :func:`pad_network`.  ``n_active is None`` means every row is real
    (the historical static-``n`` layout).  All closed forms and both event
    engines treat padded clients as structurally absent, bitwise.
    """

    p: jax.Array  # [n] routing probabilities (positive; need not sum to 1 for raw partials)
    mu_c: jax.Array  # [n] computation rates (single-server queues)
    mu_d: jax.Array  # [n] downlink rates (infinite-server queues)
    mu_u: jax.Array  # [n] uplink rates (infinite-server queues)
    mu_cs: Optional[jax.Array] = None  # scalar CS processing rate (None = infinite)
    n_active: Optional[jax.Array] = None  # traced real-client count (None = n)

    @property
    def n(self) -> int:
        return self.p.shape[0]

    @property
    def active_count(self):
        """Real-client count: the traced ``n_active`` if padded, else the
        static array length ``n``."""
        return self.n if self.n_active is None else self.n_active

    @property
    def active_mask(self) -> Optional[jax.Array]:
        """``[n] bool`` mask of real clients, or ``None`` when unpadded."""
        if self.n_active is None:
            return None
        return jnp.arange(self.n) < self.n_active

    @property
    def log_rho(self) -> jax.Array:
        """Log-loads of the client single-server (computation) queues."""
        return jnp.log(self.p) - jnp.log(self.mu_c)

    @property
    def gamma(self) -> jax.Array:
        """Per-client aggregate IS load ``gamma_i`` (Theorem 2)."""
        return self.p * (1.0 / self.mu_d + 1.0 / self.mu_u)

    @property
    def log_gamma_total(self) -> jax.Array:
        # sequential sum: padded clients (gamma = 0) must be bitwise
        # invisible, which XLA's reassociating reduce does not guarantee
        return jnp.log(seqsum(self.gamma))

    def with_cs(self, mu_cs) -> "NetworkParams":
        return self._replace(mu_cs=jnp.asarray(mu_cs, dtype=self.p.dtype))


def pad_network(params: NetworkParams, n_max: int) -> NetworkParams:
    """Pad a network to ``n_max`` client rows (the traced-``n`` convention).

    Padded rows carry zero routing mass and unit service rates, and
    ``n_active`` records the real population — so padded stations are
    load-0/visit-0 in the Buzen DP (the geometric factor of a load-0
    station is the convolution identity), padded clients receive zero
    dispatch probability in the event engines, and every downstream
    quantity is **bitwise** what the unpadded network produces (asserted in
    ``tests/test_padded_n.py``).  Mirrors the ``m_max`` convention of
    ``repro.core.batched``: one compiled program covers a whole
    mixed-population scenario batch.
    """
    n = params.n
    if n_max < n:
        raise ValueError(f"n_max={n_max} is smaller than the network's "
                         f"population n={n}")
    n_act = params.active_count  # re-padding keeps the original real count

    def pad(x, fill):
        x = jnp.asarray(x)
        return jnp.concatenate(
            [x, jnp.full((n_max - n,), fill, dtype=x.dtype)])

    return params._replace(
        p=pad(params.p, 0.0), mu_c=pad(params.mu_c, 1.0),
        mu_d=pad(params.mu_d, 1.0), mu_u=pad(params.mu_u, 1.0),
        n_active=jnp.asarray(n_act, jnp.int64))


class ClassParams(NamedTuple):
    """Class-aggregated network: ``C`` client classes with multiplicities.

    The product-form network depends on a client only through its
    ``(p, mu_c, mu_d, mu_u)`` profile, so ``count[c]`` identical clients
    collapse into one *class*: their ``count`` single-server computation
    stations enter the Buzen DP as a single negative-binomial generating
    series (the multiplicity is an analytic exponent, see
    :func:`_negbinom_series`), and the IS stations enter through the
    aggregate Poisson factor as always.  Closed forms become O(C) instead
    of O(n) — the scaling law for ``n = 10^5..10^6`` populations.

    ``p`` is the **per-member** routing mass (each member of class ``c``
    has routing probability ``p[c]``); the class as a whole carries mass
    ``count[c] * p[c]``.  Padded classes (the traced-``C`` convention of
    :func:`pad_classes`) have ``count = 0`` and ``p = 0`` and are bitwise
    invisible: their negative-binomial factor is the convolution identity
    and all class reductions are strictly sequential (``seqsum``).

    :meth:`expand` unrolls back to the per-client :class:`NetworkParams` —
    the oracle every class-space surface is tested against.
    """

    p: jax.Array  # [C] per-member routing mass (0 on padded classes)
    mu_c: jax.Array  # [C] computation rates
    mu_d: jax.Array  # [C] downlink rates
    mu_u: jax.Array  # [C] uplink rates
    count: jax.Array  # [C] integer multiplicity (0 = padded class)
    mu_cs: Optional[jax.Array] = None  # scalar CS rate (None = no CS station)

    @property
    def C(self) -> int:
        """Static class-axis length (including padded classes)."""
        return self.p.shape[0]

    @property
    def n_total(self):
        """Traced total population ``sum_c count[c]`` (padded classes add 0)."""
        return seqsum(self.count)

    @property
    def mass(self) -> jax.Array:
        """Class routing mass ``count * p`` (what the inverse-CDF routes on)."""
        return self.count.astype(self.p.dtype) * self.p

    @property
    def log_rho(self) -> jax.Array:
        """Per-member log-load of one computation station of each class."""
        return jnp.log(self.p) - jnp.log(self.mu_c)

    @property
    def gamma(self) -> jax.Array:
        """Per-member aggregate IS load ``gamma_c`` (Theorem 2)."""
        return self.p * (1.0 / self.mu_d + 1.0 / self.mu_u)

    @property
    def log_gamma_total(self) -> jax.Array:
        """Aggregate IS log-load over the whole population (sequential)."""
        return jnp.log(seqsum(self.count.astype(self.p.dtype) * self.gamma))

    def with_cs(self, mu_cs) -> "ClassParams":
        return self._replace(mu_cs=jnp.asarray(mu_cs, dtype=self.p.dtype))

    def expand(self) -> NetworkParams:
        """Unroll to the per-client network (host-side; the test oracle).

        Requires concrete counts — this is O(n) by construction and exists
        for validation and small-population interop, not for the hot path.
        """
        import numpy as np

        reps = np.asarray(self.count).astype(int)

        def rep(x):
            return jnp.asarray(np.repeat(np.asarray(x), reps))

        return NetworkParams(p=rep(self.p), mu_c=rep(self.mu_c),
                             mu_d=rep(self.mu_d), mu_u=rep(self.mu_u),
                             mu_cs=self.mu_cs)


def pad_classes(classes: ClassParams, c_max: int) -> ClassParams:
    """Pad a class set to ``c_max`` rows (the traced-``C`` convention).

    Padded classes carry zero count, zero routing mass and unit rates, so
    they are **bitwise** invisible to the class-space DP, closed forms and
    event engine (the class analogue of :func:`pad_network`): a count-0
    class has the convolution-identity negative-binomial factor, adds
    exactly 0 to every sequential class reduction, and receives zero mass
    in the routing inverse-CDF.
    """
    C = classes.C
    if c_max < C:
        raise ValueError(f"c_max={c_max} is smaller than the class-set "
                         f"size C={C}")

    def pad(x, fill):
        x = jnp.asarray(x)
        return jnp.concatenate(
            [x, jnp.full((c_max - C,), fill, dtype=x.dtype)])

    return classes._replace(
        p=pad(classes.p, 0.0), mu_c=pad(classes.mu_c, 1.0),
        mu_d=pad(classes.mu_d, 1.0), mu_u=pad(classes.mu_u, 1.0),
        count=pad(classes.count, 0))


def classes_from_network(params: NetworkParams) -> ClassParams:
    """Group identical clients of a concrete network into classes.

    Host-side: rows with bitwise-equal ``(p, mu_c, mu_d, mu_u)`` profiles
    collapse into one class (first-occurrence order preserved).  Padded
    rows (beyond ``n_active``) are dropped — re-pad with
    :func:`pad_classes` if a static class axis is needed.
    """
    import numpy as np

    n = params.n if params.n_active is None else int(params.n_active)
    cols = np.stack([np.asarray(params.p)[:n], np.asarray(params.mu_c)[:n],
                     np.asarray(params.mu_d)[:n],
                     np.asarray(params.mu_u)[:n]], axis=1)
    _, first, counts = np.unique(
        cols, axis=0, return_index=True, return_counts=True)
    order = np.argsort(first)  # undo np.unique's lexicographic sort
    cols_u = cols[np.sort(first)]
    return ClassParams(
        p=jnp.asarray(cols_u[:, 0]), mu_c=jnp.asarray(cols_u[:, 1]),
        mu_d=jnp.asarray(cols_u[:, 2]), mu_u=jnp.asarray(cols_u[:, 3]),
        count=jnp.asarray(counts[order], dtype=jnp.int64),
        mu_cs=params.mu_cs)


def _log_conv(log_a: jax.Array, log_b: jax.Array) -> jax.Array:
    """Truncated convolution in log space.

    ``out[m] = logsumexp_{k=0..m} (log_a[k] + log_b[m - k])`` for
    ``m in [0, M]`` where both inputs have shape ``[M + 1]``.
    """
    M = log_a.shape[0] - 1
    k = jnp.arange(M + 1)
    # pairs[m, k] = log_a[k] + log_b[m - k], masked to k <= m
    idx = k[None, :]
    rev = jnp.arange(M + 1)[:, None] - idx  # m - k
    valid = rev >= 0
    terms = jnp.where(valid, log_a[None, :] + log_b[jnp.clip(rev, 0)], NEG_INF)
    # contract: allow(raw-reduction): logsumexp over the k = 0..m_max convolution axis — compile-time length, never client/class padded
    return logsumexp(terms, axis=1)


def _geometric_series(log_rho: jax.Array, m_max: int) -> jax.Array:
    """``[k * log_rho for k in 0..m_max]`` — generating series of a single-server station.

    The ``k = 0`` term is pinned to exactly ``0`` so a load-0 station
    (``log_rho = -inf``, e.g. a padded client under the traced-``n``
    convention) yields ``[0, -inf, ...]`` — the log-convolution identity —
    instead of a ``0 * inf`` NaN; for finite loads the ``where`` is
    bitwise-neutral.
    """
    k = jnp.arange(m_max + 1)
    return jnp.where(k == 0, 0.0, k * log_rho)


def _poisson_series(log_load: jax.Array, m_max: int) -> jax.Array:
    """``[k log_load - log k! for k in 0..m_max]`` — series of an IS station
    (``k = 0`` pinned as in :func:`_geometric_series`)."""
    k = jnp.arange(m_max + 1)
    return jnp.where(k == 0, 0.0, k * log_load - gammaln(k + 1.0))


def _negbinom_series(log_rho: jax.Array, count: jax.Array,
                     m_max: int) -> jax.Array:
    """Generating series of ``count`` identical single-server stations.

    ``count`` stations of per-member load ``rho`` contribute the factor
    ``(1 - rho x)^{-count} = sum_j C(j + count - 1, j) rho^j x^j`` — the
    multiplicity enters as an analytic exponent instead of ``count``
    convolution folds.  In log space::

        coef[j] = j log_rho + lgamma(j + count) - lgamma(j + 1) - lgamma(count)

    ``count = 0`` (a padded class) makes every ``j >= 1`` coefficient
    ``-inf`` (``lgamma(0) = +inf``), and the ``j = 0`` term is pinned to
    exactly ``0`` — the convolution identity, mirroring the load-0 pin of
    :func:`_geometric_series`.  ``count = 1`` reduces to the geometric
    series exactly (the lgamma terms cancel).
    """
    j = jnp.arange(m_max + 1)
    cnt = jnp.asarray(count, dtype=jnp.float64)
    lw = gammaln(j + cnt) - gammaln(j + 1.0) - gammaln(cnt)
    return jnp.where(j == 0, 0.0, j * log_rho + lw)


def log_normalizing_constants(
    params: NetworkParams,
    m_max: int,
    *,
    method: str = "aggregate",
    backend: Optional[str] = None,
) -> jax.Array:
    """Log normalization constants ``log Z_{n,m}`` for ``m = 0..m_max``.

    Includes the CS single-server station when ``params.mu_cs`` is not None
    (these are the ``W_{n,m}`` constants of Proposition 19).  ``backend``
    overrides the process-wide flag (see :func:`set_backend`); the Pallas
    path only implements the ``"aggregate"`` method.
    """
    backend = _backend if backend is None else backend
    if backend == "pallas":
        if method != "aggregate":
            raise ValueError(
                f"the pallas backend only implements method='aggregate', "
                f"got {method!r}")
        from .batched import batch_log_normalizing_constants  # lazy: no cycle

        return batch_log_normalizing_constants(
            params, params.p[None, :], m_max, backend="pallas")[0]
    if backend not in _BACKENDS:
        raise ValueError(f"unknown buzen backend: {backend!r}")

    log_rho = params.log_rho

    if method == "aggregate":
        # Start from the aggregated IS factor, then fold in single-server stations.
        logZ = _poisson_series(params.log_gamma_total, m_max)
        def fold(carry, lr):
            return _log_conv(carry, _geometric_series(lr, m_max)), None
        logZ, _ = jax.lax.scan(fold, logZ, log_rho)
    elif method == "literal":
        # Station-by-station, exactly the ordering of Proposition 15:
        # n single-server computation queues, then n downlink IS, then n uplink IS.
        logZ = jnp.where(jnp.arange(m_max + 1) == 0, 0.0, NEG_INF)  # Z_{.,0}=1 only
        logZ = logZ.at[0].set(0.0)
        for i in range(params.n):
            logZ = _log_conv(logZ, _geometric_series(log_rho[i], m_max))
        for i in range(params.n):
            logZ = _log_conv(
                logZ, _poisson_series(jnp.log(params.p[i] / params.mu_d[i]), m_max)
            )
        for i in range(params.n):
            logZ = _log_conv(
                logZ, _poisson_series(jnp.log(params.p[i] / params.mu_u[i]), m_max)
            )
    else:
        raise ValueError(f"unknown method: {method}")

    if params.mu_cs is not None:
        # Multi-class CS station: the multinomial class structure of Eq. (20)
        # sums out to a geometric factor with load sum_j p_j / mu_cs (= 1/mu_cs
        # on the simplex).  Keeping the explicit sum_j p_j lets raw partials
        # d/dp_j flow through the CS station, matching Theorem 7's CS terms.
        log_load_cs = jnp.log(seqsum(params.p)) - jnp.log(params.mu_cs)
        logZ = _log_conv(logZ, _geometric_series(log_load_cs, m_max))
    return logZ


def class_log_normalizing_constants(
    classes: ClassParams,
    m_max: int,
    *,
    backend: Optional[str] = None,
) -> jax.Array:
    """Class-space Buzen DP: ``log Z_{n,m}`` in O(C m^2) instead of O(n m^2).

    The ``2n`` IS stations enter through the aggregate Poisson factor
    (as in ``method="aggregate"``), and each class's ``count`` computation
    stations fold in as ONE negative-binomial series
    (:func:`_negbinom_series`).  Agrees with
    :func:`log_normalizing_constants` on ``classes.expand()`` to f64
    roundoff (the fold order differs, so not bitwise across the two
    representations) and is **bitwise** invariant to class padding
    (:func:`pad_classes`).  ``backend="pallas"`` routes through the
    class-space TPU kernel (``repro.kernels.buzen``, float32).
    """
    backend = _backend if backend is None else backend
    if backend not in _BACKENDS:
        raise ValueError(f"unknown buzen backend: {backend!r}")
    if backend == "pallas":
        from ..kernels.buzen import buzen_classes_pallas_batched  # no cycle

        log_rho = classes.log_rho
        count = classes.count.astype(classes.p.dtype)
        if classes.mu_cs is not None:
            # the CS station is a count-1 "class" with load sum(mass)/mu_cs
            log_load_cs = jnp.log(seqsum(classes.mass)) - jnp.log(
                classes.mu_cs)
            log_rho = jnp.concatenate([log_rho, log_load_cs[None]])
            count = jnp.concatenate([count, jnp.ones((1,), count.dtype)])
        out = buzen_classes_pallas_batched(
            log_rho[None, :], count[None, :],
            classes.log_gamma_total[None], m_max)[0]
        return out.astype(classes.p.dtype)

    logZ = _poisson_series(classes.log_gamma_total, m_max)

    def fold(carry, xs):
        lr, cnt = xs
        return _log_conv(carry, _negbinom_series(lr, cnt, m_max)), None

    logZ, _ = jax.lax.scan(fold, logZ, (classes.log_rho, classes.count))
    if classes.mu_cs is not None:
        # same geometric CS factor as the per-client DP, with the class-mass
        # sequential sum standing in for sum_j p_j
        log_load_cs = jnp.log(seqsum(classes.mass)) - jnp.log(classes.mu_cs)
        logZ = _log_conv(logZ, _geometric_series(log_load_cs, m_max))
    return logZ


def log_Z_ratio(logZ: jax.Array, num: int, den: int) -> jax.Array:
    """``Z[num] / Z[den]`` in linear space, with ``Z[k<0] = 0``."""
    if num < 0:
        return jnp.zeros(())
    return jnp.exp(logZ[num] - logZ[den])


def brute_force_log_Z(params: NetworkParams, m: int) -> float:
    """Exact Z_{n,m} by state enumeration — test oracle, tiny systems only."""
    import itertools
    import numpy as np

    n = params.n
    p = np.asarray(params.p)
    mu_c = np.asarray(params.mu_c)
    mu_d = np.asarray(params.mu_d)
    mu_u = np.asarray(params.mu_u)
    stations = []  # (load, is_infinite_server)
    for i in range(n):
        stations.append((p[i] / mu_c[i], False))
    for i in range(n):
        stations.append((p[i] / mu_d[i], True))
    for i in range(n):
        stations.append((p[i] / mu_u[i], True))
    if params.mu_cs is not None:
        # contract: allow(raw-reduction): host-side numpy in the O(C(m+S-1,S-1)) literal oracle — never traced, never padded
        stations.append((float(p.sum()) / float(params.mu_cs), False))

    S = len(stations)
    total = 0.0
    # enumerate compositions of m into S parts
    for comp in itertools.combinations(range(m + S - 1), S - 1):
        prev = -1
        xs = []
        for c in comp:
            xs.append(c - prev - 1)
            prev = c
        xs.append(m + S - 2 - prev)
        term = 1.0
        for (load, is_is), x in zip(stations, xs):
            term *= load**x
            if is_is:
                import math

                term /= math.factorial(x)
        total += term
    import math

    return math.log(total)
