from .models import cnn_classifier, mlp_classifier
from .strategies import ClusterSpec, build_network_params, make_strategies
from .trainer import AsyncFLConfig, AsyncFLTrainer, TrainLog

__all__ = [
    "AsyncFLTrainer", "AsyncFLConfig", "TrainLog",
    "ClusterSpec", "build_network_params", "make_strategies",
    "cnn_classifier", "mlp_classifier",
]
