from .engine import DeviceTrainer, pad_client_data, run_strategy_grid
from .models import cnn_classifier, mlp_classifier
from .strategies import (ClusterSpec, build_network_params, make_strategies,
                         strategy_batch)
from .trainer import AsyncFLConfig, AsyncFLTrainer, TrainLog

__all__ = [
    "AsyncFLTrainer", "AsyncFLConfig", "TrainLog",
    "DeviceTrainer", "run_strategy_grid", "pad_client_data",
    "ClusterSpec", "build_network_params", "make_strategies",
    "strategy_batch",
    "cnn_classifier", "mlp_classifier",
]
