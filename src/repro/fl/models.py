"""Small pure-JAX classifiers for the FL experiments (Appendix B.1).

The paper's EMNIST/KMNIST network: two 7x7 conv layers (20, 40 channels) with
ReLU, 2x2 max-pool, and a dense softmax head.  Implemented with
``lax.conv_general_dilated`` — no flax dependency.
"""
from __future__ import annotations

from typing import Callable, NamedTuple

import jax
import jax.numpy as jnp
import numpy as np


class Model(NamedTuple):
    init: Callable  # (rng) -> params
    apply: Callable  # (params, x) -> logits


def _dense_init(rng, fan_in, fan_out):
    k1, _ = jax.random.split(rng)
    scale = np.sqrt(2.0 / fan_in)
    return {"w": jax.random.normal(k1, (fan_in, fan_out), jnp.float32) * scale,
            "b": jnp.zeros((fan_out,), jnp.float32)}


def mlp_classifier(input_dim: int, num_classes: int,
                   hidden: tuple[int, ...] = (256, 128)) -> Model:
    sizes = (input_dim,) + hidden + (num_classes,)

    def init(rng):
        keys = jax.random.split(rng, len(sizes) - 1)
        return [_dense_init(k, a, b) for k, a, b in zip(keys, sizes[:-1], sizes[1:])]

    def apply(params, x):
        h = x.reshape(x.shape[0], -1)
        for layer in params[:-1]:
            h = jax.nn.relu(h @ layer["w"] + layer["b"])
        out = params[-1]
        return h @ out["w"] + out["b"]

    return Model(init, apply)


def cnn_classifier(image_size: int, num_classes: int,
                   channels: tuple[int, int] = (20, 40),
                   kernel: int = 7) -> Model:
    """The paper's EMNIST CNN (Appendix B.1)."""

    def init(rng):
        k1, k2, k3 = jax.random.split(rng, 3)
        c1, c2 = channels
        w1 = jax.random.normal(k1, (kernel, kernel, 1, c1), jnp.float32) * np.sqrt(
            2.0 / (kernel * kernel))
        w2 = jax.random.normal(k2, (kernel, kernel, c1, c2), jnp.float32) * np.sqrt(
            2.0 / (kernel * kernel * c1))
        # SAME conv twice, then 2x2 pool
        flat = (image_size // 2) * (image_size // 2) * c2
        return {
            "conv1": {"w": w1, "b": jnp.zeros((c1,), jnp.float32)},
            "conv2": {"w": w2, "b": jnp.zeros((c2,), jnp.float32)},
            "head": _dense_init(k3, flat, num_classes),
        }

    def conv(x, w, b):
        out = jax.lax.conv_general_dilated(
            x, w, window_strides=(1, 1), padding="SAME",
            dimension_numbers=("NHWC", "HWIO", "NHWC"))
        return jax.nn.relu(out + b)

    def apply(params, x):
        # the queueing core enables x64, so host batches arrive as float64;
        # conv (unlike matmul) refuses mixed dtypes — keep the model in f32
        h = conv(x.astype(params["conv1"]["w"].dtype),
                 params["conv1"]["w"], params["conv1"]["b"])
        h = conv(h, params["conv2"]["w"], params["conv2"]["b"])
        h = jax.lax.reduce_window(h, -jnp.inf, jax.lax.max, (1, 2, 2, 1),
                                  (1, 2, 2, 1), "VALID")
        h = h.reshape(h.shape[0], -1)
        return h @ params["head"]["w"] + params["head"]["b"]

    return Model(init, apply)


def cross_entropy_loss(logits: jax.Array, labels: jax.Array) -> jax.Array:
    """Mean cross entropy; labels may be [B] (classification) or [B, S] (LM)."""
    logp = jax.nn.log_softmax(logits)
    return -jnp.mean(jnp.take_along_axis(logp, labels[..., None], axis=-1))


def accuracy(logits: jax.Array, labels: jax.Array) -> jax.Array:
    return jnp.mean(jnp.argmax(logits, axis=-1) == labels)
