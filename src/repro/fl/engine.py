"""Fused device-resident Generalized AsyncSGD training (Algorithms 1 + 2).

An entire training run — queueing dynamics (``repro.core.events``),
stale-gradient computation against the in-flight parameter-snapshot ring,
the bias-corrected ``eta / (n p_C)`` apply (optionally through the Pallas
``repro.kernels.fused_update`` kernel), energy accounting, and eval-grid
logging — executes inside ONE jitted ``lax.scan`` over update rounds, and
``jax.vmap`` batches whole runs over seeds and over padded
``(p, m, eta)`` strategy lanes.  A full Table-3 style multi-seed strategy
comparison compiles into a handful of vmapped programs (lanes are bucketed
by planned scan length so slow-throughput lanes never pay fast lanes'
padded rounds).

Snapshot ring: each in-flight task carries the parameter version it was
dispatched with (Algorithm 1).  Because the event engine re-dispatches into
the freed task-table slot, the slot index doubles as the ring index: the
ring is a ``[m_max, ...]``-stacked copy of the model pytree holding at most
``m`` live snapshots; an update reads its stale snapshot at the completed
slot and writes the post-update parameters back into the same slot for the
freshly dispatched task.

Eval-grid semantics match the host reference loop
(``AsyncFLTrainer`` with ``backend="host"``): parameters are piecewise
constant between updates, so when an update interval sweeps past grid
times the scan records one *pre-update* parameter snapshot per swept run;
after the scan, only these ``G << K`` snapshots are evaluated (on a fixed
held-out eval batch) and a ``searchsorted`` gather fills the grid — a grid
time ``t`` sees the parameters after exactly ``#{updates with time <= t}``
updates.

Host-reference contract: ``repro.core.simulator.AsyncNetworkSim`` (driven
by ``backend="host"``) remains the exact per-task-identity reference; the
engines consume randomness differently, so trainer-level cross-checks are
statistical (``tests/test_events.py``).  Known intentional deviations,
each Monte-Carlo-equivalent: fixed (seeded) eval batch instead of a fresh
draw per eval; minibatch indices drawn with replacement at full
``batch_size`` even when a client holds fewer samples; float32 parameter
updates (the host loop promotes to float64 via the x64 scale factor);
energy integrated exactly to the horizon rather than to the first event
beyond it; and when a ``max_updates`` cap binds before the horizon, the
throughput denominator is the K-th update time (the host divides by the
time of the discarded K+1-th update it popped before breaking — a ~1/K
relative difference).
"""
from __future__ import annotations
# contract: padded-n — reductions here are on the bitwise padding contract

import dataclasses
from typing import Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..core import jackson
from ..core import events
from ..core.buzen import NetworkParams
from ..core.numerics import seqsum
from ..sim.backend import resolve_backend
from .models import Model, accuracy, cross_entropy_loss

_GRID_CAP = 20_000  # static eval-grid safety bound


def _quantize_len(k: int) -> int:
    """Round a scan length up onto a x1.25 geometric grid so jit-cache
    entries are shared across seed sets (exact counts vary per trajectory)
    while keeping padded rounds bounded (~11% on average)."""
    q = 16
    while q < k:
        q = int(q * 1.25) + 1
    return q


class PaddedClientData(NamedTuple):
    """Client datasets padded to a common length for device-side sampling."""

    x: jax.Array      # [n, S_max, ...] float32
    y: jax.Array      # [n, S_max] int32
    sizes: jax.Array  # [n] int32


def pad_client_data(clients, n_total: Optional[int] = None,
                    min_samples: Optional[int] = None) -> PaddedClientData:
    """Stack per-client ``(x_i, y_i)`` datasets into padded device arrays.

    ``n_total`` (the traced-``n`` convention: the network's static
    ``n_max``) appends empty placeholder rows beyond the real clients —
    padded clients carry zero routing mass, are never dispatched, and so
    never have a minibatch sampled from their (single zero) row.
    ``min_samples`` forces the sample axis to at least that length so
    per-lane tables of different datasets stack into one ``[L, n, S_max]``
    array (minibatch draws are bounded by the *real* ``sizes``, so the
    extra zero rows are never sampled and trajectories are bitwise
    invariant to the sample-axis padding).
    """
    sizes = np.array([len(y) for _, y in clients], dtype=np.int32)
    if (sizes <= 0).any():
        raise ValueError("every client needs at least one sample")
    n_rows = len(clients) if n_total is None else int(n_total)
    if n_rows < len(clients):
        raise ValueError(f"n_total={n_rows} is smaller than the "
                         f"{len(clients)} provided clients")
    s_max = int(sizes.max())
    if min_samples is not None:
        s_max = max(s_max, int(min_samples))
    x0 = np.asarray(clients[0][0])
    xs = np.zeros((n_rows, s_max) + x0.shape[1:], dtype=np.float32)
    ys = np.zeros((n_rows, s_max), dtype=np.int32)
    for i, (x, y) in enumerate(clients):
        xs[i, :len(y)] = x
        ys[i, :len(y)] = y
    sizes = np.concatenate(
        [sizes, np.ones(n_rows - len(clients), dtype=np.int32)])
    return PaddedClientData(x=jnp.asarray(xs), y=jnp.asarray(ys),
                            sizes=jnp.asarray(sizes))


class DeviceTrainLog(NamedTuple):
    """Per-lane device arrays of one fused run (leading lane axis under
    vmap); converted to ``TrainLog`` by :meth:`DeviceTrainer.run_lanes`."""

    grid_times: jax.Array    # [G]
    grid_losses: jax.Array   # [G]
    grid_accs: jax.Array     # [G]
    grid_updates: jax.Array  # [G]
    grid_valid: jax.Array    # [G] bool
    t_end: jax.Array
    final_loss: jax.Array
    final_acc: jax.Array
    updates: jax.Array       # k_h — updates applied within the horizon
    mean_delay: jax.Array    # [n] unscaled E0[R_i] estimator
    delay_counts: jax.Array  # [n]
    throughput: jax.Array
    energy: jax.Array


def max_throughput_bound(net: NetworkParams, m) -> float:
    """Distribution-free upper bound on the update rate ``lambda``:
    ``min(single-server capacity, m / E[pure service per cycle])``."""
    p = np.asarray(net.p, dtype=np.float64)
    # contract: allow(raw-reduction): host-side numpy planning bound (scan sizing only) — the traced path never sees it
    p = p / p.sum()
    station = float(np.min(np.asarray(net.mu_c) / np.maximum(p, 1e-12)))
    if net.mu_cs is not None:
        station = min(station, float(net.mu_cs))
    # contract: allow(raw-reduction): host-side numpy planning bound (scan sizing only) — the traced path never sees it
    cycle = float(np.sum(p * (1.0 / np.asarray(net.mu_d)
                              + 1.0 / np.asarray(net.mu_c)
                              + 1.0 / np.asarray(net.mu_u))))
    if net.mu_cs is not None:
        cycle += 1.0 / float(net.mu_cs)
    return min(station, float(m) / cycle)


class DeviceTrainer:
    """Compiles and caches the fused training scan for one FL problem
    (model, client data, network rates); lanes vary ``(p, m, eta, seed)``."""

    def __init__(self, model: Model, clients, net: NetworkParams,
                 config, test_data=None, power=None,
                 loss_fn: Callable = cross_entropy_loss,
                 sim_backend: Optional[str] = None,
                 sim_interpret: Optional[bool] = None,
                 sim_chunk: int = 1,
                 trace_updates: int = 0):
        self.model = model
        self.net = net
        self.cfg = config
        self.power = power
        # event-engine backend for the queueing scans (repro.sim); None
        # defers to the process-wide REPRO_SIM_BACKEND at build time;
        # sim_interpret overrides the pallas kernel's compile/interpret auto
        self.sim_backend = sim_backend
        self.sim_interpret = sim_interpret
        # megastep chunk for the queueing scans: next_update retires up to
        # sim_chunk events per inner step — update semantics (and the event
        # trajectories) are bitwise unchanged for any value
        self.sim_chunk = int(sim_chunk)
        # repro.obs update-telemetry ring capacity (0 = tracing off: the
        # fused scan is byte-identical to the untraced program); when set,
        # each lane of :meth:`run_lanes` records its last ``trace_updates``
        # applied updates and the per-lane rings land in
        # :attr:`last_update_rings`
        self.trace_updates = int(trace_updates)
        self.last_update_rings = None
        self.n = net.n              # static row count (n_max when padded)
        # real population: the bias correction eta/(n p_C) and the reported
        # per-client statistics use the *active* count under the traced-n
        # convention (padded clients contribute no updates)
        self.n_act = (net.n if net.n_active is None
                      else int(np.asarray(net.n_active)))
        if len(clients) not in (self.n, self.n_act):
            raise ValueError(
                f"{len(clients)} clients for a network with "
                f"{self.n_act} active of {self.n} rows")
        self.data = pad_client_data(clients, n_total=self.n)
        self.has_test = test_data is not None
        if self.has_test:
            x, y = test_data
            rng = np.random.default_rng(0)
            idx = rng.permutation(len(y))[:min(config.eval_batch, len(y))]
            self.test_x = jnp.asarray(np.asarray(x)[idx], jnp.float32)
            self.test_y = jnp.asarray(np.asarray(y)[idx], jnp.int32)
        else:
            self.test_x = self.test_y = None

        def loss(params, x, y):
            return loss_fn(model.apply(params, x), y)

        self._grad_fn = jax.grad(loss)
        self._raw_loss = loss_fn
        self._jit_cache: dict = {}
        self._count_cache: dict = {}

    @classmethod
    def from_scenario(cls, scenario, model: Model, clients, *,
                      test_data=None, loss_fn: Callable = cross_entropy_loss,
                      **config_overrides) -> "DeviceTrainer":
        """Build the fused trainer from a declarative
        ``repro.scenario.Scenario`` (network rates/law, grad clip and power
        profile come from the spec; ``config_overrides`` feed
        ``AsyncFLConfig``).  Lane routing/concurrency still varies per
        :meth:`run_lanes` call — resolve them with
        ``repro.scenario.resolve_strategy`` or a ``ScenarioSuite``."""
        sim = getattr(scenario, "sim", None)
        trace = None if sim is None else getattr(sim, "trace", None)
        return cls(model, clients, scenario.params(),
                   scenario.fl_config(**config_overrides),
                   test_data=test_data, power=scenario.power(),
                   loss_fn=loss_fn,
                   sim_backend=None if sim is None else sim.backend,
                   sim_interpret=None if sim is None else sim.interpret,
                   sim_chunk=1 if sim is None else sim.chunk,
                   trace_updates=0 if trace is None else trace.updates)

    # -- static-shape planning ---------------------------------------------

    def _plan_one(self, p, m, horizon: float, net=None) -> int:
        """Per-lane *upper bound* on rounds within ``horizon``, from the
        closed-form throughput (exponential) tightened / replaced by the
        distribution-free bound otherwise.  Only used to size the cheap
        queueing-only pre-simulation; the training scan itself gets the
        exact per-lane count from :meth:`_count_updates`."""
        base = self.net if net is None else net
        lane = base._replace(p=jnp.asarray(p))
        rate = max_throughput_bound(lane, m)
        if self.cfg.distribution == "exponential":
            rate = min(rate, 1.25 * float(jackson.throughput(lane, int(m))))
        return int(horizon * rate * 1.08) + 2 * int(m) + 32

    def _count_updates(self, ps, ms, sim_keys, horizon: float,
                       max_updates: Optional[int] = None,
                       nets=None) -> np.ndarray:
        """Exact per-lane update counts within ``horizon`` (capped by
        ``max_updates`` when given — e.g. a huge horizon with a round cap
        must not size the counting scan from the horizon).

        The event trajectory is a pure function of the sim key, so a
        queueing-only scan (no gradients, no snapshots — a fraction of the
        fused scan's cost) reproduces exactly the event stream the training
        scan will see; its count sizes that scan with zero padding margin.
        ``nets`` (per-lane padded networks, see :meth:`run_lanes`) switches
        the counting program to take the network pytree as a vmapped
        argument instead of a closure constant."""
        backend = resolve_backend(self.sim_backend)
        interp = self.sim_interpret
        ck = self.sim_chunk
        net_key = None if nets is None else tuple(
            np.asarray(leaf).tobytes()
            for net in nets for leaf in jax.tree_util.tree_leaves(net))
        cache_key = (tuple(np.asarray(p, np.float64).tobytes() for p in ps),
                     tuple(int(m) for m in ms),
                     np.asarray(sim_keys).tobytes(), round(horizon, 9),
                     max_updates, backend, interp, ck, net_key)
        hit = self._count_cache.get(cache_key)
        if hit is not None:
            return hit
        lane_nets = [None] * len(ms) if nets is None else nets
        K_bound = max(self._plan_one(p, m, horizon, net=lane_net)
                      for p, m, lane_net in zip(ps, ms, lane_nets))
        if max_updates is not None:
            K_bound = min(K_bound, int(max_updates))
        K_bound = max(K_bound, 1)
        m_max = int(max(ms))
        key_stat = ("count", K_bound, m_max, round(horizon, 9), backend,
                    interp, ck, nets is not None)
        if key_stat not in self._jit_cache:
            net0, dist = self.net, self.cfg.distribution

            def count_body(net, m, key_sim):
                st = events.init_state(net, m, key_sim, m_max=m_max,
                                       distribution=dist)

                def body(st, _):
                    st, upd = events.next_update(net, st, distribution=dist,
                                                 backend=backend,
                                                 interpret=interp, chunk=ck)
                    return st, upd.time

                _, times = jax.lax.scan(body, st, None, length=K_bound)
                # contract: allow(raw-reduction): boolean count over scan steps — exact integer arithmetic under any association
                return jnp.sum(times <= horizon)

            if nets is None:
                def one(p, m, key_sim):
                    return count_body(net0._replace(p=p), m, key_sim)
            else:
                def one(net, p, m, key_sim):
                    return count_body(net._replace(p=p), m, key_sim)

            self._jit_cache[key_stat] = jax.jit(jax.vmap(one))
        p_mat = jnp.asarray(np.stack([np.asarray(p, np.float64) for p in ps]))
        m_arr = jnp.asarray(np.asarray(ms, np.int32))
        if nets is None:
            counts = np.asarray(self._jit_cache[key_stat](
                p_mat, m_arr, sim_keys))
        else:
            stacked = jax.tree_util.tree_map(
                lambda *xs: jnp.stack(xs), *nets)
            counts = np.asarray(self._jit_cache[key_stat](
                stacked, p_mat, m_arr, sim_keys))
        self._count_cache[cache_key] = counts
        return counts

    def plan_updates(self, ps, ms, horizon: float,
                     max_updates: Optional[int] = None) -> int:
        """Upper bound on the scan length covering ``horizon`` for every
        given lane (informational; the fused scans are sized by the exact
        pre-simulated counts)."""
        k = max(self._plan_one(p, m, horizon) for p, m in zip(ps, ms))
        if max_updates is not None:
            k = min(k, int(max_updates))
        return max(k, 1)

    # -- the fused run ------------------------------------------------------

    def _build(self, K: int, G: int, m_max: int, horizon: float,
               backend: str, interp: Optional[bool],
               lane_mode: bool = False, lane_power: bool = False,
               trace_updates: int = 0, chunk: int = 1):
        tr = int(trace_updates)
        ck = int(chunk)
        cfg = self.cfg
        n = self.n
        net0 = self.net
        has_test = self.has_test
        dist = cfg.distribution
        grad_clip = cfg.grad_clip
        use_fused = getattr(cfg, "use_fused_update", False)
        batch = cfg.batch_size
        delta = cfg.eval_every_time
        grad_fn = self._grad_fn
        raw_loss = self._raw_loss
        model_apply = self.model.apply
        test_x, test_y = self.test_x, self.test_y

        def evaluate(params):
            logits = model_apply(params, test_x)
            return raw_loss(logits, test_y), accuracy(logits, test_y)

        def apply_update(params, g, scale):
            # keep every op in the parameter dtype: under x64 some gradient
            # leaves and the f64 scale would otherwise promote the whole
            # update chain (and the scan carry) to f64
            g = jax.tree_util.tree_map(
                lambda v, w: v.astype(w.dtype), g, params)
            if grad_clip is not None:
                # contract: allow(raw-reduction): parameter-axis grad norm — model leaves are never padded along the client axis
                norm = jnp.sqrt(sum(jnp.sum(jnp.square(v))
                                    for v in jax.tree_util.tree_leaves(g)))
                factor = jnp.minimum(jnp.asarray(1.0, norm.dtype),
                                     grad_clip / (norm + 1e-12))
                g = jax.tree_util.tree_map(
                    lambda v: v * factor.astype(v.dtype), g)
            if use_fused:
                from ..kernels.fused_update import fused_async_update
                interpret = jax.default_backend() != "tpu"
                new, _ = fused_async_update(params, g, scale,
                                            interpret=interpret)
                return new
            # final astype guards the scan carry: any residual promotion
            # would flip the params pytree to f64 between iterations
            return jax.tree_util.tree_map(
                lambda w, v: (w - scale.astype(w.dtype) * v).astype(w.dtype),
                params, g)

        t_grid_static = jnp.arange(G) * delta

        def run_one(params0, net, s_max, data_x_flat, data_y_flat, sizes,
                    n_act, power, p, m, eta, key_sim, key_data):
            net = net._replace(p=p)
            # sequential sum: bitwise invariant to padded zero-mass clients
            p_norm = p / seqsum(p)
            st = events.init_state(net, m, key_sim, m_max=m_max,
                                   distribution=dist, t_cap=horizon)
            snaps = jax.tree_util.tree_map(
                lambda w: jnp.broadcast_to(w[None], (m_max,) + w.shape),
                params0)
            # parameters seen by the eval grid: the pre-update params of
            # step k are active on [t_{k-1}, t_k); when that interval sweeps
            # past grid points, ONE representative row (the first swept grid
            # index) records the params — all grid points swept by the same
            # interval see identical params, so the rest are reconstructed
            # by a searchsorted gather after the scan.  This keeps the
            # per-update cost free of eval forward passes (G << K) and
            # touches a single snapshot row per update.
            grid_snaps = jax.tree_util.tree_map(
                lambda w: jnp.broadcast_to(w[None], (G,) + w.shape), params0)
            if tr:
                # telemetry aux carry (repro.obs): the update ring plus the
                # per-slot snapshot write times.  Appends read (upd, g) and
                # never feed back into the training state, so the traced
                # program is bitwise identical to the untraced one
                # (tests/test_obs.py)
                from ..obs.rings import update_ring_append, update_ring_init
                aux0 = (update_ring_init(tr),
                        jnp.zeros((m_max,), jnp.float64))
            else:
                aux0 = ()

            def body(carry, _):
                st, params, snaps, grid_snaps, prev_t, dkey, aux = carry
                st, upd = events.next_update(net, st, distribution=dist,
                                             power=power, backend=backend,
                                             interpret=interp, chunk=ck)
                live = upd.time <= horizon
                j, c = upd.slot, upd.client
                stale = jax.tree_util.tree_map(lambda s: s[j], snaps)
                dkey, kb = jax.random.split(dkey)
                idx = (c * s_max
                       + jax.random.randint(kb, (batch,), 0, sizes[c]))
                xb, yb = data_x_flat[idx], data_y_flat[idx]
                # bias correction over the REAL population (Algorithm 2):
                # padded rows have p = 0 and are never drawn as C_k
                scale = eta / (n_act * p_norm[c])
                g = grad_fn(stale, xb, yb)
                if tr:
                    ring, snap_t = aux
                    # contract: allow(raw-reduction): parameter-axis grad norm — model leaves are never padded along the client axis
                    sq = [jnp.sum(jnp.square(v.astype(jnp.float64)))
                          for v in jax.tree_util.tree_leaves(g)]
                    gnorm = jnp.sqrt(sum(sq))
                    ring = update_ring_append(
                        ring, time=upd.time, client=c, staleness=upd.delay,
                        grad_norm=gnorm, snapshot_age=upd.time - snap_t[j],
                        valid=live)
                    # like the snaps write, no live-mask on snap_t: time is
                    # monotone, so a post-horizon write is only ever read by
                    # appends whose valid gate is already False
                    aux = (ring, snap_t.at[j].set(upd.time))
                new_params = apply_update(params, g, scale)
                new_params = jax.tree_util.tree_map(
                    lambda a, b: jnp.where(live, a, b), new_params, params)
                # first grid point inside [prev_t, t_k), if any
                g0 = jnp.searchsorted(t_grid_static, prev_t, side="left")
                g0c = jnp.clip(g0, 0, G - 1)
                cross = ((t_grid_static[g0c] >= prev_t)
                         & (t_grid_static[g0c] < upd.time))
                grid_snaps = jax.tree_util.tree_map(
                    lambda s, w: s.at[g0c].set(jnp.where(cross, w, s[g0c])),
                    grid_snaps, params)
                # the ring write needs no live-mask: time is monotone, so
                # post-horizon writes are never read by a live update
                snaps = jax.tree_util.tree_map(
                    lambda s, w: s.at[j].set(w), snaps, new_params)
                out = (upd.time, c, upd.delay, live)
                return (st, new_params, snaps, grid_snaps, upd.time, dkey,
                        aux), out

            (st, paramsK, _, grid_snaps, _, _, aux), outs = jax.lax.scan(
                body, (st, params0, snaps, grid_snaps,
                       jnp.zeros((), jnp.float64), key_data, aux0),
                None, length=K)
            times, clients_k, delays, live = outs

            if has_test:
                final_loss, final_acc = evaluate(paramsK)
                snap_losses, snap_accs = jax.vmap(evaluate)(grid_snaps)
            else:
                final_loss = final_acc = jnp.zeros(())
                snap_losses = snap_accs = jnp.zeros((G,))

            # contract: allow(raw-reduction): int32 count of live updates over the scan axis — exact integer arithmetic under any association
            k_h = jnp.sum(live.astype(jnp.int32))
            delay_sum = jnp.zeros((n,)).at[clients_k].add(
                jnp.where(live, delays.astype(jnp.float64), 0.0))
            delay_cnt = jnp.zeros((n,), jnp.int32).at[clients_k].add(
                live.astype(jnp.int32))
            mean_delay = jnp.where(delay_cnt > 0,
                                   delay_sum / jnp.maximum(delay_cnt, 1), 0.0)
            t_last = jnp.max(jnp.where(live, times, 0.0))
            t_end = jnp.where(k_h < K, horizon, t_last)
            # host reference divides by the time of the first update beyond
            # the horizon (the loop's break event) when one exists
            t_break = jnp.min(jnp.where(live, jnp.inf, times))
            denom = jnp.where(jnp.isfinite(t_break), t_break, t_last)
            thr = jnp.where(denom > 0, k_h / jnp.maximum(denom, 1e-12), 0.0)

            live_times = jnp.where(live, times, jnp.inf)
            kg = jnp.searchsorted(live_times, t_grid_static, side="right")
            # grid points swept by the same update interval share kg; gather
            # each from the representative (first) index of its kg-run
            g_first = jnp.searchsorted(kg, kg, side="left")
            grid_losses = jnp.where(kg < k_h, snap_losses[g_first],
                                    final_loss)
            grid_accs = jnp.where(kg < k_h, snap_accs[g_first], final_acc)
            dlog = DeviceTrainLog(
                grid_times=t_grid_static, grid_losses=grid_losses,
                grid_accs=grid_accs, grid_updates=kg.astype(jnp.int32),
                grid_valid=t_grid_static < t_end, t_end=t_end,
                final_loss=final_loss, final_acc=final_acc, updates=k_h,
                mean_delay=mean_delay, delay_counts=delay_cnt,
                throughput=thr, energy=st.energy)
            if tr:
                return dlog, paramsK, aux[0]
            return dlog, paramsK

        if not lane_mode:
            data = self.data
            # flat views: one row-gather per minibatch instead of slicing
            # the whole client dataset out first
            s_max0 = data.x.shape[1]
            dxf0 = data.x.reshape((n * s_max0,) + data.x.shape[2:])
            dyf0 = data.y.reshape((n * s_max0,))
            sizes0, n_act0, power0 = data.sizes, self.n_act, self.power

            def single(params0, p, m, eta, key_sim, key_data):
                return run_one(params0, net0, s_max0, dxf0, dyf0, sizes0,
                               n_act0, power0, p, m, eta, key_sim, key_data)

            return jax.jit(jax.vmap(single))

        # lane mode: network, client table, real-population count (and
        # optionally the power profile) ride along each lane as vmapped
        # arguments, so lanes with different populations/datasets share one
        # resident program — the mixed-n train bucket.  The in-program
        # reshape to flat views is a free metadata op under XLA.
        if lane_power:
            def single_lanes(params0, net, dx, dy, sizes, n_act, power,
                             p, m, eta, key_sim, key_data):
                s_max = dx.shape[1]
                dxf = dx.reshape((n * s_max,) + dx.shape[2:])
                dyf = dy.reshape((n * s_max,))
                return run_one(params0, net, s_max, dxf, dyf, sizes, n_act,
                               power, p, m, eta, key_sim, key_data)
        else:
            def single_lanes(params0, net, dx, dy, sizes, n_act,
                             p, m, eta, key_sim, key_data):
                s_max = dx.shape[1]
                dxf = dx.reshape((n * s_max,) + dx.shape[2:])
                dyf = dy.reshape((n * s_max,))
                return run_one(params0, net, s_max, dxf, dyf, sizes, n_act,
                               None, p, m, eta, key_sim, key_data)

        return jax.jit(jax.vmap(single_lanes))

    def _run_bucket(self, ps, ms, etas, sim_keys, init_keys, data_keys,
                    horizon: float, K: int, m_max: int, lane_args=None):
        """One jitted, vmapped call over lanes sharing a scan length.

        ``lane_args`` (stacked ``(nets, x, y, sizes, n_acts, powers)``)
        selects the lane-mode program where the network and client table
        are vmapped arguments rather than closure constants."""
        G = int(horizon / self.cfg.eval_every_time) + 1
        if G > _GRID_CAP:
            raise ValueError(
                f"eval grid of {G} points exceeds the device cap "
                f"{_GRID_CAP}; coarsen eval_every_time or use the host "
                f"backend")
        backend = resolve_backend(self.sim_backend)
        interp = self.sim_interpret
        ck = self.sim_chunk
        params0 = jax.vmap(self.model.init)(init_keys)
        p_mat = jnp.asarray(np.stack([np.asarray(p, np.float64) for p in ps]))
        m_arr = jnp.asarray(np.asarray(ms, np.int32))
        eta_arr = jnp.asarray(np.asarray(etas, np.float64))
        tr = self.trace_updates
        if lane_args is not None:
            nets, lx, ly, lsizes, n_acts, powers = lane_args
            key_stat = ("lanes", K, G, m_max, round(horizon, 9), backend,
                        interp, lx.shape[1:], powers is not None,
                        nets.mu_cs is not None, tr, ck)
            if key_stat not in self._jit_cache:
                self._jit_cache[key_stat] = self._build(
                    K, G, m_max, horizon, backend, interp,
                    lane_mode=True, lane_power=powers is not None,
                    trace_updates=tr, chunk=ck)
            fn = self._jit_cache[key_stat]
            args = (params0, nets, lx, ly, lsizes, n_acts)
            if powers is not None:
                args = args + (powers,)
            return fn(*args, p_mat, m_arr, eta_arr, sim_keys, data_keys)
        key_stat = (K, G, m_max, round(horizon, 9), backend, interp, tr, ck)
        if key_stat not in self._jit_cache:
            self._jit_cache[key_stat] = self._build(K, G, m_max, horizon,
                                                    backend, interp,
                                                    trace_updates=tr,
                                                    chunk=ck)
        fn = self._jit_cache[key_stat]
        return fn(params0, p_mat, m_arr, eta_arr, sim_keys, data_keys)

    def run_lanes(self, ps, ms, etas, seeds, horizon_time: float, *,
                  max_updates: Optional[int] = None, init_keys=None,
                  nets=None, lane_clients=None, lane_powers=None):
        """Run ``L`` lanes (routing ``ps[L, n]``, concurrency ``ms[L]``,
        step size ``etas[L]``, seed ``seeds[L]``) as jitted, vmapped scans.

        A queueing-only pre-simulation (same keys, hence bit-identical
        event streams) counts each lane's exact rounds within the horizon;
        lanes are then bucketed by that count (within 1.25x) so the fused
        scans run with near-zero padded rounds and a slow-throughput lane
        never pays a fast lane's scan length.  Each bucket is one compile,
        cached across calls.  Returns
        ``(list[TrainLog], final_params_stacked)`` in input lane order.

        Mixed-``n`` lanes: ``nets`` gives each lane its own network, padded
        (``pad_network``) to this trainer's static row count; it requires
        ``lane_clients`` (per-lane client datasets, padded here into one
        ``[L, n, S_max]`` table) and optionally ``lane_powers`` (per-lane
        power profiles padded to the same rows).  Under the padding
        contract the per-lane trajectories are bitwise identical to a
        single-lane run of each scenario at its own size."""
        from .trainer import TrainLog  # local: trainer imports this module

        L = len(ms)
        horizon = float(horizon_time)
        lane_mode = nets is not None
        if lane_mode:
            if len(nets) != L:
                raise ValueError(f"{len(nets)} lane networks for {L} lanes")
            if lane_clients is None or len(lane_clients) != L:
                raise ValueError("per-lane networks require per-lane "
                                 "client datasets (lane_clients)")
            if lane_powers is not None and len(lane_powers) != L:
                raise ValueError(
                    f"{len(lane_powers)} lane powers for {L} lanes")
            for net in nets:
                if net.n != self.n:
                    raise ValueError(
                        f"lane network has {net.n} rows; pad_network it "
                        f"to this trainer's {self.n}")
            n_acts = [net.n if net.n_active is None
                      else int(np.asarray(net.n_active)) for net in nets]
            s_top = max(max(len(y) for _, y in cl) for cl in lane_clients)
            tables = [pad_client_data(cl, n_total=self.n, min_samples=s_top)
                      for cl in lane_clients]
            lane_x = jnp.stack([t.x for t in tables])
            lane_y = jnp.stack([t.y for t in tables])
            lane_sizes = jnp.stack([t.sizes for t in tables])
            stacked_nets = jax.tree_util.tree_map(
                lambda *xs: jnp.stack(xs), *nets)
            stacked_pw = (None if lane_powers is None else
                          jax.tree_util.tree_map(
                              lambda *xs: jnp.stack(xs), *lane_powers))
            n_act_arr = jnp.asarray(np.asarray(n_acts, np.float64))
        elif lane_clients is not None or lane_powers is not None:
            raise ValueError("lane_clients/lane_powers need nets")
        # sim/data streams always derive from the lane seeds (matching the
        # host loop, whose sim is seeded by cfg.seed); ``init_keys`` only
        # overrides the model-initialization keys (the host loop's rng_key)
        seed_keys = jnp.stack([jax.random.PRNGKey(int(s)) for s in seeds])
        all_init_keys = seed_keys if init_keys is None else jnp.asarray(
            init_keys)
        if all_init_keys.shape[0] != L:
            raise ValueError(
                f"init_keys has {all_init_keys.shape[0]} rows for {L} lanes")
        all_sim_keys = jax.vmap(lambda k: jax.random.fold_in(k, 1))(seed_keys)
        all_data_keys = jax.vmap(lambda k: jax.random.fold_in(k, 2))(seed_keys)
        counts = self._count_updates(ps, ms, all_sim_keys, horizon,
                                     max_updates, nets=nets)
        # +1: include the first update beyond the horizon (the host loop's
        # break event), which pins t_end and the throughput denominator
        plans = [int(c) + 1 for c in counts]
        if max_updates is not None:
            plans = [min(k, int(max_updates)) for k in plans]
        plans = [max(k, 1) for k in plans]
        # group by the quantized count: bucket shapes (and hence compiled
        # programs) are stable across seed sets that land in the same
        # quantum, and a slow lane never pays a fast lane's scan length
        buckets: dict = {}
        for i in range(L):
            buckets.setdefault(_quantize_len(plans[i]), []).append(i)

        dlogs = [None] * L
        finals = [None] * L
        rings = [None] * L if self.trace_updates else None
        m_max = int(max(ms))  # shared: bucket membership must not change shapes
        for K, idx in sorted(buckets.items()):
            if max_updates is not None:
                K = min(K, int(max_updates))
            rows = jnp.asarray(idx)
            lane_args = None
            if lane_mode:
                take = lambda t: jax.tree_util.tree_map(
                    lambda a: a[rows], t)
                lane_args = (take(stacked_nets), lane_x[rows], lane_y[rows],
                             lane_sizes[rows], n_act_arr[rows],
                             None if stacked_pw is None else take(stacked_pw))
            out = self._run_bucket(
                [ps[i] for i in idx], [ms[i] for i in idx],
                [etas[i] for i in idx], all_sim_keys[rows],
                all_init_keys[rows], all_data_keys[rows], horizon, K, m_max,
                lane_args=lane_args)
            if self.trace_updates:
                dlog, fin, ring = out
            else:
                dlog, fin = out
            for row, i in enumerate(idx):
                dlogs[i] = jax.tree_util.tree_map(lambda a: a[row], dlog)
                finals[i] = jax.tree_util.tree_map(lambda a: a[row], fin)
                if rings is not None:
                    rings[i] = jax.tree_util.tree_map(lambda a: a[row], ring)
        # per-lane update rings in input lane order (None when tracing off);
        # decode with repro.obs.rings.decode
        self.last_update_rings = rings
        final_params = jax.tree_util.tree_map(
            lambda *xs: jnp.stack(xs), *finals)

        logs = []
        for i in range(L):
            dlog = dlogs[i]
            if self.has_test:
                valid = np.asarray(dlog.grid_valid)
                times = [float(t) for t in np.asarray(dlog.grid_times)[valid]]
                losses = [float(v) for v in np.asarray(dlog.grid_losses)[valid]]
                accs = [float(v) for v in np.asarray(dlog.grid_accs)[valid]]
                upds = [int(v) for v in np.asarray(dlog.grid_updates)[valid]]
                times.append(float(dlog.t_end))
                losses.append(float(dlog.final_loss))
                accs.append(float(dlog.final_acc))
                upds.append(int(dlog.updates))
            else:
                times = losses = accs = upds = []
            logs.append(TrainLog(
                times=times, accuracies=accs, losses=losses, updates=upds,
                mean_delay=np.asarray(dlog.mean_delay)[
                    :(n_acts[i] if lane_mode else self.n_act)],
                throughput=float(dlog.throughput),
                energy=float(dlog.energy)))
        return logs, final_params


@dataclasses.dataclass
class StrategyGridResult:
    """Result of :func:`run_strategy_grid`: ``logs[name][seed_idx]``."""

    logs: dict
    seeds: tuple
    lanes: int
    updates_per_lane: int


def run_strategy_grid(model: Model, clients, net: NetworkParams,
                      strategies: dict, config, *, horizon_time: float,
                      seeds=(0,), etas=None, test_data=None, power=None,
                      trainer: Optional[DeviceTrainer] = None,
                      loss_fn: Callable = cross_entropy_loss
                      ) -> StrategyGridResult:
    """One jitted multi-seed strategy comparison: the full
    ``strategies x seeds`` grid runs as a single vmapped scan.

    ``strategies`` maps name -> ``(p, m)`` (the :func:`make_strategies`
    output); ``etas`` maps name -> step size (or a scalar for all).
    """
    if trainer is None:
        trainer = DeviceTrainer(model, clients, net, config,
                                test_data=test_data, power=power,
                                loss_fn=loss_fn)
    names = list(strategies)
    if etas is None:
        etas = {name: config.eta for name in names}
    elif not isinstance(etas, dict):
        etas = {name: float(etas) for name in names}
    ps, ms, es, ss = [], [], [], []
    for name in names:
        p, m = strategies[name]
        for s in seeds:
            ps.append(np.asarray(p, np.float64))
            ms.append(int(m))
            es.append(float(etas[name]))
            ss.append(int(s))
    logs, _ = trainer.run_lanes(ps, ms, es, ss, horizon_time)
    n_seeds = len(seeds)
    per_name = {name: logs[i * n_seeds:(i + 1) * n_seeds]
                for i, name in enumerate(names)}
    return StrategyGridResult(logs=per_name, seeds=tuple(seeds),
                              lanes=len(ms),
                              updates_per_lane=trainer.plan_updates(
                                  ps, ms, float(horizon_time)))
