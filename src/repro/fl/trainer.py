"""Generalized AsyncSGD as a runnable training system (Algorithms 1 + 2).

The CS loop (Algorithm 1) is driven by the exact discrete-event network
simulator (``repro.core.simulator.AsyncNetworkSim``), so the parameter
staleness experienced during training is *exactly* the queueing process the
theory analyzes: each dispatched task carries a snapshot of the global
parameters; when its uplink (or CS-buffer service) completes, the gradient —
computed at the stale snapshot on the owning client's local data — is applied
with the bias-corrected step ``eta / (n p_C)`` (Algorithm 1, line 6).

Client behaviour (Algorithm 2: FIFO queues, local mini-batch sampling) is
implicit in the network simulator's queues; the actual gradient math runs as
a single jitted function on the host accelerator, which is the standard way
to *simulate* an FL deployment faithfully while using one machine.
"""
from __future__ import annotations

import dataclasses
import time as _time
from typing import Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..core.buzen import NetworkParams
from ..core.simulator import AsyncNetworkSim
from .models import Model, accuracy, cross_entropy_loss


@dataclasses.dataclass
class AsyncFLConfig:
    eta: float = 0.05                 # base learning rate
    batch_size: int = 128
    distribution: str = "exponential"  # service-time law (Section 5.3.3)
    seed: int = 0
    eval_every_time: float = 10.0     # evaluate on a wall-clock grid
    eval_batch: int = 512
    grad_clip: Optional[float] = None  # constrains G (Section 2.5)


@dataclasses.dataclass
class TrainLog:
    times: list          # wall-clock (virtual) eval times
    accuracies: list
    losses: list
    updates: list        # cumulative update count at eval points
    # [n] unscaled per-client conditional mean delay E0[R_i] (same estimator
    # as SimStats.mean_delay); E0[D_i] of Thm 2 is p_i * mean_delay[i]
    mean_delay: np.ndarray | None = None
    throughput: float = 0.0
    energy: float = 0.0

    def time_to_accuracy(self, target: float) -> float:
        """First virtual time at which test accuracy reaches ``target``."""
        for t, a in zip(self.times, self.accuracies):
            if a >= target:
                return t
        return float("inf")


class AsyncFLTrainer:
    """Train ``model`` with Generalized AsyncSGD under routing ``p`` and
    concurrency ``m`` on a heterogeneous client population."""

    def __init__(
        self,
        model: Model,
        client_data: list[tuple[np.ndarray, np.ndarray]],  # [(x_i, y_i)] per client
        net: NetworkParams,
        m: int,
        config: AsyncFLConfig = AsyncFLConfig(),
        test_data: Optional[tuple[np.ndarray, np.ndarray]] = None,
        power=None,
        loss_fn: Callable = cross_entropy_loss,
    ):
        self.model = model
        self.clients = client_data
        self.net = net
        self.m = m
        self.cfg = config
        self.test = test_data
        self.power = power
        self.n = net.n
        self.p = np.asarray(net.p, dtype=np.float64)
        self.p = self.p / self.p.sum()
        self.rng = np.random.default_rng(config.seed + 1)

        def loss(params, x, y):
            return loss_fn(model.apply(params, x), y)

        grad_fn = jax.grad(loss)

        @jax.jit
        def compute_update(current, stale, x, y, scale):
            g = grad_fn(stale, x, y)
            if config.grad_clip is not None:
                norm = jnp.sqrt(sum(jnp.sum(jnp.square(v))
                                    for v in jax.tree_util.tree_leaves(g)))
                factor = jnp.minimum(1.0, config.grad_clip / (norm + 1e-12))
                g = jax.tree_util.tree_map(lambda v: v * factor, g)
            new = jax.tree_util.tree_map(lambda w, v: w - scale * v, current, g)
            return new

        self._compute_update = compute_update

        @jax.jit
        def evaluate(params, x, y):
            logits = model.apply(params, x)
            return loss_fn(logits, y), accuracy(logits, y)

        self._evaluate = evaluate

    def _batch(self, client: int):
        x, y = self.clients[client]
        idx = self.rng.integers(0, len(y), size=min(self.cfg.batch_size, len(y)))
        return jnp.asarray(x[idx]), jnp.asarray(y[idx])

    def run(self, horizon_time: float, max_updates: int = 10**9,
            rng_key=None) -> TrainLog:
        rng_key = jax.random.PRNGKey(self.cfg.seed) if rng_key is None else rng_key
        params = self.model.init(rng_key)
        sim = AsyncNetworkSim(self.net, self.m,
                              distribution=self.cfg.distribution,
                              seed=self.cfg.seed, power=self.power)
        payloads = {tid: params for _, tid in sim.initial_tasks}

        log = TrainLog(times=[], accuracies=[], losses=[], updates=[])
        next_eval = 0.0
        k = 0
        while True:
            ev = sim.next_update()
            if ev.time > horizon_time or k >= max_updates:
                break
            # grid points strictly before the update event see the
            # pre-update snapshot (the update lands exactly at ev.time)
            while next_eval < ev.time:
                self._log_eval(log, params, next_eval, k)
                next_eval += self.cfg.eval_every_time
            stale = payloads.pop(ev.task_id)
            x, y = self._batch(ev.client)
            scale = self.cfg.eta / (self.n * self.p[ev.client])
            params = self._compute_update(params, stale, x, y, scale)
            k += 1
            # Algorithm 1 lines 7-8: route a fresh task carrying w_{k+1}
            _, tid = sim.dispatch_next()
            payloads[tid] = params

            # a grid point landing exactly on the update instant sees the
            # post-update params (exact hits are real under deterministic
            # service laws, where event times are rational sums)
            while ev.time >= next_eval:
                self._log_eval(log, params, next_eval, k)
                next_eval += self.cfg.eval_every_time
        # fill grid points between the last update and the horizon, then a
        # final eval at the horizon itself
        t_end = min(sim.t, horizon_time)
        while next_eval < t_end:
            self._log_eval(log, params, next_eval, k)
            next_eval += self.cfg.eval_every_time
        self._log_eval(log, params, t_end, k)
        stats_delay = np.where(sim.delay_cnt > 0,
                               sim.delay_sum / np.maximum(sim.delay_cnt, 1), 0.0)
        # E0[D_i] of Theorem 2 is the *unscaled* per-client conditional mean,
        # exactly what AsyncNetworkSim.run reports (SimStats.mean_delay)
        log.mean_delay = stats_delay
        log.throughput = k / max(sim.t, 1e-9)
        log.energy = sim.energy
        self.final_params = params
        return log

    def _log_eval(self, log: TrainLog, params, t: float, k: int):
        if self.test is None:
            return
        x, y = self.test
        idx = self.rng.integers(0, len(y), size=min(self.cfg.eval_batch, len(y)))
        loss, acc = self._evaluate(params, jnp.asarray(x[idx]), jnp.asarray(y[idx]))
        log.times.append(float(t))
        log.losses.append(float(loss))
        log.accuracies.append(float(acc))
        log.updates.append(k)
