"""Generalized AsyncSGD as a runnable training system (Algorithms 1 + 2).

Two interchangeable execution backends behind one API:

  * ``backend="device"`` (default) — the fused engine of
    ``repro.fl.engine``: queueing dynamics (``repro.core.events``),
    stale-gradient computation against the snapshot ring, the
    bias-corrected ``eta / (n p_C)`` apply, energy accounting and eval-grid
    logging all execute inside ONE jitted ``lax.scan``;
    :meth:`AsyncFLTrainer.run_seeds` vmaps whole runs over seeds.

  * ``backend="host"`` — the original event-at-a-time loop driven by the
    exact per-task-identity reference simulator
    (``repro.core.simulator.AsyncNetworkSim``).  This is the semantic
    reference the device engine is cross-checked against
    (``tests/test_events.py``); the two consume randomness differently, so
    same-seed trajectories differ while all statistics agree in
    distribution.

In both backends each dispatched task carries a snapshot of the global
parameters; when its uplink (or CS-buffer service) completes, the gradient —
computed at the stale snapshot on the owning client's local data — is
applied with the bias-corrected step ``eta / (n p_C)`` (Algorithm 1,
line 6).
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..core.buzen import NetworkParams
from ..core.simulator import AsyncNetworkSim
from .models import Model, accuracy, cross_entropy_loss


@dataclasses.dataclass
class AsyncFLConfig:
    eta: float = 0.05                 # base learning rate
    batch_size: int = 128
    distribution: str = "exponential"  # registered timing law (Section 5.3.3)
    seed: int = 0
    eval_every_time: float = 10.0     # evaluate on a wall-clock grid
    eval_batch: int = 512
    grad_clip: Optional[float] = None  # constrains G (Section 2.5)
    backend: str = "device"           # "device" (fused scan) | "host" (ref)
    use_fused_update: bool = False    # Pallas fused apply (device backend)

    def __post_init__(self):
        # eager timing-law validation: an unknown law used to surface only
        # deep inside the first jit trace — fail at construction instead,
        # with the registered laws in the message
        from ..scenario.laws import get_law

        get_law(self.distribution)
        if self.backend not in ("device", "host"):
            raise ValueError(f"unknown backend: {self.backend!r}; "
                             "expected 'device' or 'host'")


@dataclasses.dataclass
class TrainLog:
    times: list          # wall-clock (virtual) eval times
    accuracies: list
    losses: list
    updates: list        # cumulative update count at eval points
    # [n] unscaled per-client conditional mean delay E0[R_i] (same estimator
    # as SimStats.mean_delay); E0[D_i] of Thm 2 is p_i * mean_delay[i]
    mean_delay: np.ndarray | None = None
    throughput: float = 0.0
    energy: float = 0.0

    def time_to_accuracy(self, target: float) -> float:
        """First virtual time at which test accuracy reaches ``target``.

        Robust to empty logs and to NaN accuracy readings (e.g. a diverged
        model): non-finite entries are skipped, no-hit returns ``inf``.
        """
        for t, a in zip(self.times, self.accuracies):
            if np.isfinite(a) and a >= target:
                return t
        return float("inf")


class AsyncFLTrainer:
    """Train ``model`` with Generalized AsyncSGD under routing ``p`` and
    concurrency ``m`` on a heterogeneous client population."""

    def __init__(
        self,
        model: Model,
        client_data: list,  # [(x_i, y_i)] per client
        net: NetworkParams,
        m: int,
        config: AsyncFLConfig = AsyncFLConfig(),
        test_data=None,
        power=None,
        loss_fn: Callable = cross_entropy_loss,
    ):
        self.model = model
        self.clients = client_data
        self.net = net
        self.m = m
        self.cfg = config
        self.test = test_data
        self.power = power
        self.loss_fn = loss_fn
        self.n = net.n
        self.p = np.asarray(net.p, dtype=np.float64)
        self.p = self.p / self.p.sum()
        self.rng = np.random.default_rng(config.seed + 1)
        self._device = None  # lazily built fused engine

        def loss(params, x, y):
            return loss_fn(model.apply(params, x), y)

        grad_fn = jax.grad(loss)

        @jax.jit
        def compute_update(current, stale, x, y, scale):
            g = grad_fn(stale, x, y)
            if config.grad_clip is not None:
                norm = jnp.sqrt(sum(jnp.sum(jnp.square(v))
                                    for v in jax.tree_util.tree_leaves(g)))
                factor = jnp.minimum(1.0, config.grad_clip / (norm + 1e-12))
                g = jax.tree_util.tree_map(lambda v: v * factor, g)
            new = jax.tree_util.tree_map(lambda w, v: w - scale * v, current, g)
            return new

        self._compute_update = compute_update

        @jax.jit
        def evaluate(params, x, y):
            logits = model.apply(params, x)
            return loss_fn(logits, y), accuracy(logits, y)

        self._evaluate = evaluate

    @classmethod
    def from_scenario(cls, scenario, model: Model, client_data: list, *,
                      test_data=None, loss_fn: Callable = cross_entropy_loss,
                      **config_overrides) -> "AsyncFLTrainer":
        """Construct a trainer from a declarative ``repro.scenario.Scenario``
        — the strategy registry resolves ``(p, m)``, the network spec the
        rates/law, the learning spec eta/clipping; ``config_overrides`` feed
        ``AsyncFLConfig`` (e.g. ``batch_size=32, backend="host"``)."""
        from ..scenario.suite import resolve_strategy

        p, m = resolve_strategy(scenario)
        return cls(model, client_data, scenario.params(p), m,
                   config=scenario.fl_config(**config_overrides),
                   test_data=test_data, power=scenario.power(),
                   loss_fn=loss_fn)

    # -- device backend -----------------------------------------------------

    def _device_trainer(self):
        if self._device is None:
            from .engine import DeviceTrainer  # lazy: keeps import cheap

            self._device = DeviceTrainer(
                self.model, self.clients, self.net, self.cfg,
                test_data=self.test, power=self.power, loss_fn=self.loss_fn)
        return self._device

    def run_seeds(self, horizon_time: float, seeds,
                  max_updates: Optional[int] = None) -> list[TrainLog]:
        """Fused multi-seed batch: every seed's full run executes inside one
        jitted, vmapped scan (device backend regardless of ``cfg.backend``)."""
        dev = self._device_trainer()
        seeds = list(seeds)
        L = len(seeds)
        logs, _ = dev.run_lanes([self.p] * L, [self.m] * L,
                                [self.cfg.eta] * L, seeds,
                                horizon_time, max_updates=max_updates)
        return logs

    def _run_device(self, horizon_time: float, max_updates: Optional[int],
                    rng_key=None) -> TrainLog:
        dev = self._device_trainer()
        init_keys = None if rng_key is None else jnp.stack([rng_key])
        logs, final_params = dev.run_lanes(
            [self.p], [self.m], [self.cfg.eta], [self.cfg.seed],
            horizon_time, max_updates=max_updates, init_keys=init_keys)
        self.final_params = jax.tree_util.tree_map(lambda a: a[0],
                                                   final_params)
        return logs[0]

    # -- public -------------------------------------------------------------

    def run(self, horizon_time: float, max_updates: int = 10**9,
            rng_key=None) -> TrainLog:
        if self.cfg.backend == "device":
            cap = None if max_updates >= 10**9 else max_updates
            return self._run_device(horizon_time, cap, rng_key)
        if self.cfg.backend != "host":
            raise ValueError(f"unknown backend: {self.cfg.backend!r}")
        return self._run_host(horizon_time, max_updates, rng_key)

    # -- host reference loop (exact per-task-identity semantics) ------------

    def _batch(self, client: int):
        x, y = self.clients[client]
        idx = self.rng.integers(0, len(y), size=min(self.cfg.batch_size, len(y)))
        return jnp.asarray(x[idx]), jnp.asarray(y[idx])

    def _run_host(self, horizon_time: float, max_updates: int = 10**9,
                  rng_key=None) -> TrainLog:
        rng_key = jax.random.PRNGKey(self.cfg.seed) if rng_key is None else rng_key
        params = self.model.init(rng_key)
        sim = AsyncNetworkSim(self.net, self.m,
                              distribution=self.cfg.distribution,
                              seed=self.cfg.seed, power=self.power)
        payloads = {tid: params for _, tid in sim.initial_tasks}

        log = TrainLog(times=[], accuracies=[], losses=[], updates=[])
        next_eval = 0.0
        k = 0
        while True:
            ev = sim.next_update()
            if ev.time > horizon_time or k >= max_updates:
                break
            # grid points strictly before the update event see the
            # pre-update snapshot (the update lands exactly at ev.time)
            while next_eval < ev.time:
                self._log_eval(log, params, next_eval, k)
                next_eval += self.cfg.eval_every_time
            stale = payloads.pop(ev.task_id)
            x, y = self._batch(ev.client)
            scale = self.cfg.eta / (self.n * self.p[ev.client])
            params = self._compute_update(params, stale, x, y, scale)
            k += 1
            # Algorithm 1 lines 7-8: route a fresh task carrying w_{k+1}
            _, tid = sim.dispatch_next()
            payloads[tid] = params

            # a grid point landing exactly on the update instant sees the
            # post-update params (exact hits are real under deterministic
            # service laws, where event times are rational sums)
            while ev.time >= next_eval:
                self._log_eval(log, params, next_eval, k)
                next_eval += self.cfg.eval_every_time
        # fill grid points between the last update and the horizon, then a
        # final eval at the horizon itself
        t_end = min(sim.t, horizon_time)
        while next_eval < t_end:
            self._log_eval(log, params, next_eval, k)
            next_eval += self.cfg.eval_every_time
        self._log_eval(log, params, t_end, k)
        stats_delay = np.where(sim.delay_cnt > 0,
                               sim.delay_sum / np.maximum(sim.delay_cnt, 1), 0.0)
        # E0[D_i] of Theorem 2 is the *unscaled* per-client conditional mean,
        # exactly what AsyncNetworkSim.run reports (SimStats.mean_delay)
        log.mean_delay = stats_delay
        log.throughput = k / max(sim.t, 1e-9)
        log.energy = sim.energy
        self.final_params = params
        return log

    def _log_eval(self, log: TrainLog, params, t: float, k: int):
        if self.test is None:
            return
        x, y = self.test
        idx = self.rng.integers(0, len(y), size=min(self.cfg.eval_batch, len(y)))
        loss, acc = self._evaluate(params, jnp.asarray(x[idx]), jnp.asarray(y[idx]))
        log.times.append(float(t))
        log.losses.append(float(loss))
        log.accuracies.append(float(acc))
        log.updates.append(k)
