"""The paper's client clusters and scheduling strategies (Sections 5.3/6.5).

``ClusterSpec`` encodes Table 1 (service rates) and Table 4 (power profiles);
``make_strategies`` derives the five configurations compared in the paper:

  * ``asyncsgd``        — uniform routing, m = n              [29, Alg. 2]
  * ``max_throughput``  — p*_lambda, m = n
  * ``round_opt``       — p*_K, m = n                         [31, 2]
  * ``time_opt``        — (p*_tau, m*_tau)                    (proposed)
  * ``energy_opt``      — (p*_E, m = 1), closed form Eq. 16
  * ``joint(rho)``      — (p*_rho, m*_rho), Eq. 18
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax.numpy as jnp
import numpy as np

from ..core import (LearningConstants, NetworkParams, PowerProfile,
                    energy_optimal_routing, joint_optimal, make_round_objective,
                    make_throughput_objective, minimal_energy,
                    optimize_routing, time_optimal)


@dataclasses.dataclass
class ClusterSpec:
    """One client cluster row of Table 1 / Table 4."""

    name: str
    mu_c: float
    mu_u: float
    mu_d: float
    count: int
    kappa: float = 0.0   # DVFS energy coefficient (Table 4)
    P_u: float = 0.0
    P_d: float = 0.0


# Table 1 — the paper's main experimental population (n = 100).
PAPER_CLUSTERS_TABLE1 = [
    ClusterSpec("A", 10.0, 2.0, 2.5, 15, kappa=0.08, P_u=5.0, P_d=3.0),
    ClusterSpec("B", 0.3, 9.0, 10.0, 15, kappa=200.0, P_u=15.0, P_d=10.0),
    ClusterSpec("C", 5.0, 6.0, 7.0, 20, kappa=0.25, P_u=4.0, P_d=3.0),
    ClusterSpec("D", 0.15, 0.1, 0.12, 40, kappa=14400.0, P_u=0.5, P_d=0.2),
    ClusterSpec("E", 12.0, 10.0, 11.0, 10, kappa=1.50, P_u=50.0, P_d=40.0),
]

# Table 6 — the round-complexity experiment population (Appendix H).
PAPER_CLUSTERS_TABLE6 = [
    ClusterSpec("A", 10.0, 2.0, 2.5, 15),
    ClusterSpec("B", 2.5, 8.0, 9.0, 35),
    ClusterSpec("C", 5.0, 5.0, 6.0, 30),
    ClusterSpec("D", 0.5, 0.8, 1.1, 15),
    ClusterSpec("E", 15.0, 10.0, 11.0, 5),
]


def build_network_params(clusters: list[ClusterSpec],
                         scale: int = 1,
                         mu_cs: Optional[float] = None) -> NetworkParams:
    """Expand cluster rows into per-client rate vectors (optionally scaling
    the population down by ``scale`` for CPU-budget experiments)."""
    mu_c, mu_d, mu_u = [], [], []
    for c in clusters:
        cnt = max(1, c.count // scale)
        mu_c += [c.mu_c] * cnt
        mu_d += [c.mu_d] * cnt
        mu_u += [c.mu_u] * cnt
    n = len(mu_c)
    params = NetworkParams(
        p=jnp.full((n,), 1.0 / n),
        mu_c=jnp.asarray(mu_c), mu_d=jnp.asarray(mu_d), mu_u=jnp.asarray(mu_u))
    if mu_cs is not None:
        params = params.with_cs(mu_cs)
    return params


def build_power_profile(clusters: list[ClusterSpec], scale: int = 1,
                        P_cs: Optional[float] = None) -> PowerProfile:
    kappa, P_u, P_d, mu_c = [], [], [], []
    for c in clusters:
        cnt = max(1, c.count // scale)
        kappa += [c.kappa] * cnt
        P_u += [c.P_u] * cnt
        P_d += [c.P_d] * cnt
        mu_c += [c.mu_c] * cnt
    return PowerProfile.from_dvfs(
        jnp.asarray(kappa), jnp.asarray(mu_c), jnp.asarray(P_u),
        jnp.asarray(P_d), P_cs=None if P_cs is None else jnp.asarray(P_cs))


def cluster_labels(clusters: list[ClusterSpec], scale: int = 1) -> list[str]:
    out = []
    for c in clusters:
        out += [c.name] * max(1, c.count // scale)
    return out


def make_strategies(
    params: NetworkParams,
    consts: LearningConstants,
    power: Optional[PowerProfile] = None,
    *,
    rho: float = 0.1,
    m_max: Optional[int] = None,
    steps: int = 300,
    which: tuple = ("asyncsgd", "max_throughput", "round_opt", "time_opt"),
) -> dict[str, tuple[np.ndarray, int]]:
    """Return {name: (p, m)} for the requested strategies."""
    n = params.n
    m_full = n
    m_max = m_max or n + max(8, n // 4)
    out: dict[str, tuple[np.ndarray, int]] = {}

    if "asyncsgd" in which:
        out["asyncsgd"] = (np.full(n, 1.0 / n), m_full)

    if "max_throughput" in which:
        res = optimize_routing(make_throughput_objective(params), n, m_full,
                               steps=steps)
        out["max_throughput"] = (np.asarray(res.p), m_full)

    if "round_opt" in which:
        res = optimize_routing(make_round_objective(params, consts), n, m_full,
                               steps=steps)
        out["round_opt"] = (np.asarray(res.p), m_full)

    if "time_opt" in which:
        res = time_optimal(params, consts, m_max=m_max, steps=steps)
        out["time_opt"] = (np.asarray(res.p), res.m)

    if "energy_opt" in which:
        assert power is not None
        out["energy_opt"] = (np.asarray(energy_optimal_routing(params, power)), 1)

    if "joint" in which:
        assert power is not None
        if "time_opt" in out:
            p_tau, m_tau = out["time_opt"]
            from ..core import wallclock_time
            tau_star = float(wallclock_time(params._replace(p=jnp.asarray(p_tau)),
                                            m_tau, consts))
        else:
            tau_star = time_optimal(params, consts, m_max=m_max,
                                    steps=steps).value
        e_star = float(minimal_energy(params, consts, power))
        res = joint_optimal(params, consts, power, rho, tau_star, e_star,
                            m_max=m_max, steps=steps)
        out["joint"] = (np.asarray(res.p), res.m)

    return out


# The paper's step sizes for the Table-3 comparison: max-throughput needs a
# 20x-reduced learning rate to stay stable (Section 5.3).  Single source of
# truth for benchmarks and examples.
DEFAULT_ETA = 0.05
MAX_THROUGHPUT_ETA = 0.01


def default_etas(strategies) -> dict:
    """Per-strategy step sizes for a ``make_strategies`` result."""
    return {name: MAX_THROUGHPUT_ETA if name == "max_throughput"
            else DEFAULT_ETA for name in strategies}


def strategy_batch(strategies: dict, etas=None
                   ) -> tuple[list, np.ndarray, np.ndarray, np.ndarray]:
    """Flatten a ``make_strategies`` result into padded lane arrays for the
    fused device engine (``repro.fl.engine``): returns
    ``(names, p_mat [S, n], m_vec [S], eta_vec [S])``.

    ``etas`` is an optional ``{name: step size}`` override (scalar allowed);
    defaults to :func:`default_etas`.
    """
    names = list(strategies)
    if etas is None:
        etas = {}
    elif not isinstance(etas, dict):
        etas = {name: float(etas) for name in names}
    defaults = default_etas(names)
    p_mat = np.stack([np.asarray(strategies[k][0], np.float64) for k in names])
    m_vec = np.asarray([int(strategies[k][1]) for k in names])
    eta_vec = np.asarray([float(etas.get(k, defaults[k])) for k in names])
    return names, p_mat, m_vec, eta_vec
