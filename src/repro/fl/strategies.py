"""Deprecation shims: cluster tables and the strategy factory, pre-Scenario.

The declarative home of everything here is ``repro.scenario``:

  * :class:`ClusterSpec` and the paper's Table-1/Table-6 populations live in
    ``repro.scenario.spec`` (re-exported below);
  * network/power construction is ``NetworkSpec.from_clusters(...).params()``
    / ``EnergySpec.from_clusters(...).profile(...)``;
  * the five scheduling configurations (Sections 5.3/6.5) are entries in
    the strategy registry (``repro.scenario.suite``):

      - ``asyncsgd``        — uniform routing, m = n          [29, Alg. 2]
      - ``max_throughput``  — p*_lambda, m = n
      - ``round_opt``       — p*_K, m = n                     [31, 2]
      - ``time_opt``        — (p*_tau, m*_tau)                (proposed)
      - ``energy_opt``      — (p*_E, m = 1), closed form Eq. 16
      - ``joint``           — (p*_rho, m*_rho), Eq. 18

:func:`make_strategies` keeps its seed signature and output format
(``{name: (p, m)}``) but dispatches through that registry, so
``@strategy``-registered extensions are immediately available to every seed
call site.
"""
from __future__ import annotations

from typing import Optional

import numpy as np

from ..core import LearningConstants, NetworkParams, PowerProfile
# re-exports for seed call sites (the canonical home is repro.scenario.spec)
from ..scenario.spec import (DEFAULT_ETA, MAX_THROUGHPUT_ETA,  # noqa: F401
                             PAPER_CLUSTERS_TABLE1, PAPER_CLUSTERS_TABLE6,
                             ClusterSpec, expand_clusters)


def build_network_params(clusters: list[ClusterSpec],
                         scale: int = 1,
                         mu_cs: Optional[float] = None) -> NetworkParams:
    """Shim: ``NetworkSpec.from_clusters(...).params()``."""
    from ..scenario.spec import NetworkSpec

    return NetworkSpec.from_clusters(clusters, scale, mu_cs=mu_cs).params()


def build_power_profile(clusters: list[ClusterSpec], scale: int = 1,
                        P_cs: Optional[float] = None) -> PowerProfile:
    """Shim: ``EnergySpec.from_clusters(...).profile(network)``."""
    from ..scenario.spec import EnergySpec, NetworkSpec

    return EnergySpec.from_clusters(clusters, scale, P_cs=P_cs).profile(
        NetworkSpec.from_clusters(clusters, scale))


def cluster_labels(clusters: list[ClusterSpec], scale: int = 1) -> list[str]:
    return list(expand_clusters(clusters, scale)[0])


def make_strategies(
    params: NetworkParams,
    consts: LearningConstants,
    power: Optional[PowerProfile] = None,
    *,
    rho: float = 0.1,
    m_max: Optional[int] = None,
    steps: int = 300,
    which: tuple = ("asyncsgd", "max_throughput", "round_opt", "time_opt"),
    search: str = "batched",
) -> dict[str, tuple[np.ndarray, int]]:
    """Return ``{name: (p, m)}`` for the requested strategies.

    Shim over the strategy registry: each name resolves through
    ``repro.scenario.STRATEGIES`` with a shared cache, so ``joint`` reuses
    ``time_opt``'s tau* exactly as the seed implementation did, and
    ``search="pruned"`` selects the coarse-to-fine concurrency search.
    """
    from ..scenario.registry import STRATEGIES
    from ..scenario.suite import ResolveContext, default_m_max

    m_max = m_max or default_m_max(params.n)
    out: dict[str, tuple[np.ndarray, int]] = {}
    cache: dict = {}
    for name in which:
        ctx = ResolveContext(
            params=params, consts=consts, power=power, rho=rho, m=None,
            m_max=m_max, steps=steps, search=search, resolved=out,
            cache=cache)
        out[name] = STRATEGIES.get(name)(ctx)
    return out


def default_etas(strategies) -> dict:
    """Per-strategy step sizes for a ``make_strategies`` result."""
    return {name: MAX_THROUGHPUT_ETA if name == "max_throughput"
            else DEFAULT_ETA for name in strategies}


def strategy_batch(strategies: dict, etas=None
                   ) -> tuple[list, np.ndarray, np.ndarray, np.ndarray]:
    """Flatten a ``make_strategies`` result into padded lane arrays for the
    fused device engine (``repro.fl.engine``): returns
    ``(names, p_mat [S, n], m_vec [S], eta_vec [S])``.

    ``etas`` is an optional ``{name: step size}`` override (scalar allowed);
    defaults to :func:`default_etas`.
    """
    names = list(strategies)
    if etas is None:
        etas = {}
    elif not isinstance(etas, dict):
        etas = {name: float(etas) for name in names}
    defaults = default_etas(names)
    p_mat = np.stack([np.asarray(strategies[k][0], np.float64) for k in names])
    m_vec = np.asarray([int(strategies[k][1]) for k in names])
    eta_vec = np.asarray([float(etas.get(k, defaults[k])) for k in names])
    return names, p_mat, m_vec, eta_vec
