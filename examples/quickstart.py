"""Quickstart: the paper's queueing analysis in ten lines.

Builds the paper's Table-1 client population, computes closed-form relative
delays / throughput / wall-clock complexity, optimizes routing+concurrency,
and cross-checks against the discrete-event simulator.

Run:  PYTHONPATH=src python examples/quickstart.py
"""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax.numpy as jnp
import numpy as np

from repro.core import (expected_relative_delay, simulate_stats, throughput,
                        time_optimal, wallclock_time)
from repro.core.simulator import AsyncNetworkSim
from repro.scenario import (NetworkSpec, PAPER_CLUSTERS_TABLE1, Scenario,
                            StrategySpec)


def main():
    # the paper's heterogeneous population (Table 1), scaled to 11 clients,
    # as ONE declarative spec (network + constants + strategy)
    scn = Scenario(
        network=NetworkSpec.from_clusters(PAPER_CLUSTERS_TABLE1, 10),
        strategy=StrategySpec("time_opt", steps=200),
        name="quickstart")
    net, consts = scn.params(), scn.consts
    n, m = scn.n, scn.n

    # closed-form stationary analysis (Theorem 2 / Proposition 4)
    delays = expected_relative_delay(net, m)
    lam = float(throughput(net, m))
    print(f"n={n} clients, m={m} tasks (AsyncSGD defaults)")
    print(f"  E0[D_i] = {np.round(np.asarray(delays), 2)}  "
          f"(sum = {float(jnp.sum(delays)):.2f} = m-1)")
    print(f"  throughput lambda = {lam:.3f} updates/unit-time")
    print(f"  E0[tau_eps]      = {float(wallclock_time(net, m, consts)):.1f}")

    # validate against both simulators: the jitted device event engine (the
    # hot path) and the exact per-task-identity host reference
    dev = simulate_stats(net, m, 40_000, warmup=5_000, seed=0)
    sim = AsyncNetworkSim(net, m, seed=0)
    stats = sim.run(40_000, warmup=5_000)
    print(f"  device-engine lambda = {float(dev.throughput):.3f}, "
          f"host-reference lambda = {stats.throughput:.3f}  "
          f"(closed form {lam:.3f})")

    # jointly optimize routing + concurrency for wall-clock time (Section 5):
    # one jitted sweep over every candidate m (batched engine)
    res = time_optimal(net, consts, m_max=n + 6, steps=200)
    tau_uni = float(wallclock_time(net, m, consts))
    print(f"\ntime-optimized: m* = {res.m}, "
          f"tau* = {res.value:.1f} vs uniform {tau_uni:.1f} "
          f"({100 * (1 - res.value / tau_uni):.0f}% faster)")
    print(f"  p* = {np.round(np.asarray(res.p), 4)}")


if __name__ == "__main__":
    main()
