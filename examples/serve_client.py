"""Talk to the suite server: concurrent mixed load over one socket.

Boot the server in one terminal::

    PYTHONPATH=src python -m repro.serve --socket /tmp/repro-serve.sock

then run this client in another::

    PYTHONPATH=src python examples/serve_client.py \
        --socket /tmp/repro-serve.sock

It pipelines an analyze, two mixed-population simulates and a train
request from two concurrent connections, prints the streamed events and
the server's ``stats``, and (``--check``) asserts every payload is
bitwise-equal to a direct in-process ``ScenarioSuite.run`` — the CI
serve leg runs exactly this with ``--wait --check --shutdown``.
"""
import argparse
import json
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np  # noqa: E402


def make_scenarios():
    from repro.core.complexity import LearningConstants
    from repro.scenario import (DataSpec, LearningSpec, NetworkSpec,
                                Scenario, StrategySpec)

    consts = LearningConstants(L=1.0, delta=1.0, sigma=1.0, M=2.0, G=5.0,
                               eps=1.0)

    def scn(n, seed):
        rng = np.random.default_rng(seed)
        return Scenario(
            network=NetworkSpec(mu_c=list(rng.uniform(1.0, 2.0, n)),
                                mu_d=[2.0] * n, mu_u=[2.0] * n),
            learning=LearningSpec(consts=consts),
            strategy=StrategySpec("explicit",
                                  p=list(np.full(n, 1.0 / n)), m=2),
            data=DataSpec(dataset="synthetic", num_classes=2,
                          samples_per_class=6))

    return scn(3, seed=1), scn(5, seed=2), scn(4, seed=3), scn(2, seed=4)


MODEL = {"kind": "mlp", "input_dim": 28 * 28, "num_classes": 2,
         "hidden": [4]}
SIM = dict(num_updates=80)
TRAIN = dict(horizon_time=4.0, batch_size=4, eval_every_time=2.0,
             model=MODEL)


def direct_payload(scn, mode, seeds, **options):
    from repro.fl.models import mlp_classifier
    from repro.scenario import ScenarioSuite
    from repro.serve.protocol import encode_entry

    if mode == "train":
        options = dict(options)
        spec = options.pop("model")
        options["model"] = mlp_classifier(spec["input_dim"],
                                          spec["num_classes"],
                                          hidden=tuple(spec["hidden"]))
    res = ScenarioSuite(scn, seeds=seeds).run(mode=mode, **options)
    (entry,) = res.entries.values()
    return encode_entry(mode, entry)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--socket",
                    default=os.environ.get("REPRO_SERVE_SOCKET",
                                           "/tmp/repro-serve.sock"))
    ap.add_argument("--wait", type=float, default=0.0, metavar="SECONDS",
                    help="poll for the socket to appear (server booting)")
    ap.add_argument("--check", action="store_true",
                    help="assert payloads == direct ScenarioSuite runs")
    ap.add_argument("--shutdown", action="store_true",
                    help="drain the server when done")
    args = ap.parse_args(argv)

    deadline = time.monotonic() + args.wait
    while not os.path.exists(args.socket):
        if time.monotonic() >= deadline:
            break
        time.sleep(0.2)

    from repro.serve.client import ServeClient

    sim3, sim5, ana, tr = make_scenarios()
    with ServeClient(args.socket, timeout=600) as a, \
            ServeClient(args.socket, timeout=600) as b:
        # two connections pipeline into the same micro-batch windows:
        # the two simulates coalesce into ONE padded dispatch
        ra1 = a.submit(sim3, mode="simulate", seeds=(0, 1), **SIM)
        rb1 = b.submit(sim5, mode="simulate", seeds=(0, 1), **SIM)
        ra2 = a.submit(ana, mode="analyze")
        rb2 = b.submit(tr, mode="train", seeds=(0,), **TRAIN)
        got = {
            "simulate/n=3": (a, ra1, sim3, "simulate", (0, 1), SIM),
            "simulate/n=5": (b, rb1, sim5, "simulate", (0, 1), SIM),
            "analyze": (a, ra2, ana, "analyze", (0,), {}),
            "train": (b, rb2, tr, "train", (0,), TRAIN),
        }
        failures = 0
        for label, (client, rid, scn, mode, seeds, opts) in got.items():
            payload = client.unwrap(client.collect(rid))
            events = [e["event"] for e in client.events_for(rid)]
            sched = [e for e in client.events_for(rid)
                     if e["event"] == "scheduled"]
            width = (f" ({sched[0]['requests']} req / "
                     f"{sched[0]['lanes']} lanes)" if sched else " (cached)")
            print(f"{label}: {events or ['cached']}{width}")
            if args.check:
                direct = direct_payload(scn, mode, seeds, **opts)
                ok = json.dumps(payload) == json.dumps(direct)
                print(f"  bitwise-equal to direct run: {ok}")
                failures += 0 if ok else 1
        stats = a.stats()
        print("server stats:",
              json.dumps({k: v for k, v in stats["counters"].items()
                          if k.startswith("serve.")}, indent=1))
        if args.shutdown:
            print("shutdown:", a.shutdown())
    if args.check and failures:
        print(f"FAILED: {failures} payload(s) diverged", file=sys.stderr)
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
