"""Paper-scale simulation: the full n = 100 population, m = 132 tasks,
through the ``repro.sim`` backend subsystem.

The Section-6 experiments need stationary statistics of the Fig. 1 closed
network at its real size.  One lane is inherently sequential (one event at
a time), so the sweep batches lanes — seeds here — into one compiled
program (``backend="batched"``); the ``reference`` backend runs the same
lanes one by one and is the semantic (bitwise) baseline, and ``pallas``
moves the per-event table transition into the TPU kernel
(``repro.kernels.events``; interpret mode off-TPU).

Select the backend per scenario (``SimSpec``), per call (``backend=``), or
process-wide::

    REPRO_SIM_BACKEND=batched PYTHONPATH=src python examples/paper_scale_sim.py

Run:  PYTHONPATH=src python examples/paper_scale_sim.py
"""
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import numpy as np

from repro.core import jackson
from repro.scenario import (NetworkSpec, PAPER_CLUSTERS_TABLE1, Scenario,
                            ScenarioSuite, SimSpec, StrategySpec)

N_SEEDS = 6
M = 132
UPDATES, WARMUP = 600, 400


def main():
    net = NetworkSpec.from_clusters(PAPER_CLUSTERS_TABLE1, scale=1)
    scn = Scenario(
        network=net,
        strategy=StrategySpec("explicit", p=np.full(net.n, 1.0 / net.n),
                              m=M),
        sim=SimSpec(backend="batched"),   # pinned: survives to_dict()/hash()
        name="paper_scale")
    print(f"n={scn.n} clients, m={M} in-flight tasks, "
          f"{N_SEEDS} seed lanes, backend={scn.sim.backend!r}")

    suite = ScenarioSuite(scn, seeds=range(N_SEEDS))
    t0 = time.time()
    res = suite.run(mode="simulate", num_updates=UPDATES, warmup=WARMUP,
                    m_max=M)
    stats = res.entries["paper_scale"]
    jax.block_until_ready(stats[-1].throughput)
    print(f"  {res.lanes} lanes in {res.programs} compiled program(s), "
          f"{time.time() - t0:.1f}s")

    lam = float(jackson.throughput(scn.params(scn.strategy.p), M))
    thr = np.mean([float(s.throughput) for s in stats])
    p = np.asarray(scn.strategy.p)
    stale = np.mean([float(np.sum(p / p.sum() * np.asarray(s.mean_delay)))
                     for s in stats])
    print(f"  throughput {thr:.3f} vs closed form {lam:.3f} "
          f"({abs(thr - lam) / lam:.1%})")
    print(f"  staleness sum p_i E0[R_i] = {stale:.1f} vs m-1 = {M - 1} "
          f"({abs(stale - (M - 1)) / (M - 1):.1%})")

    # identical re-run: served from the suite-level result cache
    t0 = time.time()
    res2 = suite.run(mode="simulate", num_updates=UPDATES, warmup=WARMUP,
                     m_max=M)
    print(f"  re-run: {res2.cache_hits} cache hit(s) in "
          f"{time.time() - t0:.3f}s")


if __name__ == "__main__":
    main()
