"""Energy-aware scheduling (Section 6): trace the time-energy Pareto
frontier over rho and print the rho=0.1 operating point the paper recommends.

Run:  PYTHONPATH=src python examples/joint_energy_opt.py
"""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax.numpy as jnp
import numpy as np

from repro.core import (LearningConstants, energy_complexity,
                        energy_optimal_routing, minimal_energy, pareto_sweep,
                        time_optimal, wallclock_time)
from repro.fl.strategies import (PAPER_CLUSTERS_TABLE1, build_network_params,
                                 build_power_profile, cluster_labels)


def main():
    net = build_network_params(PAPER_CLUSTERS_TABLE1, scale=10)
    power = build_power_profile(PAPER_CLUSTERS_TABLE1, scale=10)
    labels = np.array(cluster_labels(PAPER_CLUSTERS_TABLE1, scale=10))
    consts = LearningConstants(L=1, delta=1, sigma=1, M=2, G=5, eps=1)
    n = net.n
    m_max = n + 6

    # one jitted sweep over m = 2..n+6 replaces the warm-started loop
    tau_res = time_optimal(net, consts, m_max=m_max, steps=200)
    e_star = float(minimal_energy(net, consts, power))
    p_e = energy_optimal_routing(net, power)
    print(f"time-optimal:   m*={tau_res.m} tau*={tau_res.value:.1f}")
    print(f"energy-optimal: m=1 E*={e_star:.1f} "
          f"(closed form p_i ∝ 1/sqrt(E_i), Eq. 16)")

    # the whole frontier — every (rho, m) pair — in ONE further sweep,
    # with rho entering as the batched objective context
    rhos = (0.0, 0.1, 0.3, 0.5, 0.8, 1.0)
    _, per_rho = pareto_sweep(net, consts, power, rhos, tau_res.value, e_star,
                              m_max=m_max, steps=200)

    print("\nPareto frontier (Eq. 18):")
    print(f"{'rho':>5} {'m*':>4} {'tau':>9} {'energy':>10}  type-E weight")
    for rho, res in zip(rhos, per_rho):
        pp = jnp.asarray(res.p)
        tau = float(wallclock_time(net._replace(p=pp), res.m, consts))
        en = float(energy_complexity(net._replace(p=pp), res.m, consts, power))
        pE = np.asarray(pp)[labels == "E"].mean()
        print(f"{rho:5.1f} {res.m:4d} {tau:9.1f} {en:10.1f}  {pE * 100:.2f}%")


if __name__ == "__main__":
    main()
