"""Energy-aware scheduling (Section 6): trace the time-energy Pareto
frontier over rho and print the rho=0.1 operating point the paper recommends.

Declarative setup: ONE energy-aware Scenario supplies the network, power
profile and constants; the strategy registry resolves the time-optimal
reference and the closed-form energy optimum, and the whole frontier —
every (rho, m) pair — runs as ONE further batched sweep.

Run:  PYTHONPATH=src python examples/joint_energy_opt.py
"""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax.numpy as jnp
import numpy as np

from repro.core import (energy_complexity, minimal_energy, pareto_sweep,
                        wallclock_time)
from repro.scenario import (EnergySpec, NetworkSpec, PAPER_CLUSTERS_TABLE1,
                            Scenario, ScenarioSuite, StrategySpec)


def main():
    scn = Scenario(
        network=NetworkSpec.from_clusters(PAPER_CLUSTERS_TABLE1, 10),
        energy=EnergySpec.from_clusters(PAPER_CLUSTERS_TABLE1, 10),
        strategy=StrategySpec("time_opt", steps=200, m_max=None),
        name="joint_energy")
    net, power, consts = scn.params(), scn.power(), scn.consts
    labels = np.array(scn.network.labels)
    m_max = scn.n + 6

    # the registry resolves both reference points ((p*_tau, m*_tau) via one
    # jitted sweep over m = 2..n+6; (p*_E, m=1) in closed form)
    suite = ScenarioSuite.strategy_grid(scn, ("time_opt", "energy_opt"),
                                        m_max=m_max)
    ana = suite.run(mode="analyze")
    tau_star = ana.entries["time_opt"]["tau"]
    e_star = float(minimal_energy(net, consts, power))
    print(f"time-optimal:   m*={ana.entries['time_opt']['m']} "
          f"tau*={tau_star:.1f}")
    print(f"energy-optimal: m=1 E*={e_star:.1f} "
          f"(closed form p_i ∝ 1/sqrt(E_i), Eq. 16)")

    # the whole frontier — every (rho, m) pair — in ONE further sweep,
    # with rho entering as the batched objective context
    rhos = (0.0, 0.1, 0.3, 0.5, 0.8, 1.0)
    _, per_rho = pareto_sweep(net, consts, power, rhos, tau_star, e_star,
                              m_max=m_max, steps=200)

    print("\nPareto frontier (Eq. 18):")
    print(f"{'rho':>5} {'m*':>4} {'tau':>9} {'energy':>10}  type-E weight")
    for rho, res in zip(rhos, per_rho):
        pp = jnp.asarray(res.p)
        tau = float(wallclock_time(net._replace(p=pp), res.m, consts))
        en = float(energy_complexity(net._replace(p=pp), res.m, consts, power))
        pE = np.asarray(pp)[labels == "E"].mean()
        print(f"{rho:5.1f} {res.m:4d} {tau:9.1f} {en:10.1f}  {pE * 100:.2f}%")


if __name__ == "__main__":
    main()
