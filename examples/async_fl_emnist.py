"""End-to-end driver: Generalized AsyncSGD training on synthetic-EMNIST.

Reproduces the paper's Section 5.3 comparison (Figure 3 / Table 3): four
scheduling strategies training the same CNN on a Dirichlet(0.2) non-IID
heterogeneous client population, measured in *virtual wall-clock time* from
the exact Jackson-network event simulator.

Run:  PYTHONPATH=src python examples/async_fl_emnist.py [--horizon 240]
"""
import argparse
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax.numpy as jnp
import numpy as np

from repro.core import LearningConstants
from repro.data import (dirichlet_partition, make_synthetic_image_dataset,
                        train_test_split)
from repro.fl import (AsyncFLConfig, AsyncFLTrainer, cnn_classifier,
                      make_strategies)
from repro.fl.strategies import PAPER_CLUSTERS_TABLE1, build_network_params


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--horizon", type=float, default=240.0)
    ap.add_argument("--scale", type=int, default=10)
    ap.add_argument("--target", type=float, default=0.6)
    ap.add_argument("--distribution", default="exponential")
    args = ap.parse_args()

    net = build_network_params(PAPER_CLUSTERS_TABLE1, scale=args.scale)
    consts = LearningConstants(L=1, delta=1, sigma=1, M=2, G=5, eps=1)
    strategies = make_strategies(net, consts, steps=200, m_max=net.n + 6)

    full = make_synthetic_image_dataset(num_classes=10, samples_per_class=120)
    train, test = train_test_split(full, 0.2, seed=1)
    parts = dirichlet_partition(train.y, net.n, alpha=0.2, seed=0)
    clients = [(train.x[i], train.y[i]) for i in parts]

    results = {}
    for name, (p, m) in strategies.items():
        eta = 0.01 if name == "max_throughput" else 0.05
        model = cnn_classifier(28, 10)
        tr = AsyncFLTrainer(
            model, clients, net._replace(p=jnp.asarray(p)), m,
            config=AsyncFLConfig(eta=eta, batch_size=32,
                                 eval_every_time=args.horizon / 40,
                                 distribution=args.distribution,
                                 grad_clip=5.0),
            test_data=(test.x, test.y))
        log = tr.run(horizon_time=args.horizon)
        t_hit = log.time_to_accuracy(args.target)
        results[name] = t_hit
        print(f"{name:>15}: m={m:3d}  final_acc={log.accuracies[-1]:.3f}  "
              f"updates={log.updates[-1]:6d}  "
              f"t(acc>={args.target})={t_hit:.1f}")
    base = results.get("asyncsgd", float("inf"))
    if np.isfinite(results.get("time_opt", np.inf)) and np.isfinite(base):
        print(f"\ntime-optimized reaches {args.target:.0%} "
              f"{100 * (1 - results['time_opt'] / base):.1f}% faster than "
              f"AsyncSGD (paper Table 3: 29-46%)")


if __name__ == "__main__":
    main()
