"""End-to-end driver: Generalized AsyncSGD training on synthetic-EMNIST.

Reproduces the paper's Section 5.3 comparison (Figure 3 / Table 3): four
scheduling strategies training the same CNN on a Dirichlet(0.2) non-IID
heterogeneous client population, measured in *virtual wall-clock time*.

The whole experiment is FIVE lines of declarative Scenario API (network
spec -> strategy grid -> ``suite.run(mode="train")``) — the strategy
registry resolves each (p, m), and the strategies x seeds grid runs on the
fused device engine (``repro.fl.engine``) as bucketed jitted scans.
``--backend host`` restores the event-at-a-time reference loop driven by
the exact per-task-identity simulator (``AsyncFLTrainer.from_scenario``).

Run:  PYTHONPATH=src python examples/async_fl_emnist.py [--horizon 240]
"""
import argparse
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np

from repro.data import (dirichlet_partition, make_synthetic_image_dataset,
                        train_test_split)
from repro.fl import AsyncFLTrainer, cnn_classifier
from repro.scenario import (LearningSpec, NetworkSpec,
                            PAPER_CLUSTERS_TABLE1, Scenario, ScenarioSuite)

STRATEGIES = ("asyncsgd", "max_throughput", "round_opt", "time_opt")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--horizon", type=float, default=240.0)
    ap.add_argument("--scale", type=int, default=10)
    ap.add_argument("--target", type=float, default=0.6)
    ap.add_argument("--distribution", default="exponential")
    ap.add_argument("--seeds", type=int, default=1,
                    help="seeds per strategy (device backend vmaps them all)")
    ap.add_argument("--backend", choices=("device", "host"), default="device")
    args = ap.parse_args()

    # the 5-line declarative setup: one spec drives everything below
    net = NetworkSpec.from_clusters(PAPER_CLUSTERS_TABLE1, args.scale,
                                    law=args.distribution)
    base = Scenario(network=net, learning=LearningSpec(grad_clip=5.0))
    suite = ScenarioSuite.strategy_grid(base, STRATEGIES,
                                        seeds=range(args.seeds),
                                        steps=200, m_max=net.n + 6)

    full = make_synthetic_image_dataset(num_classes=10, samples_per_class=120)
    train, test = train_test_split(full, 0.2, seed=1)
    parts = dirichlet_partition(train.y, net.n, alpha=0.2, seed=0)
    clients = [(train.x[i], train.y[i]) for i in parts]

    results = {}
    if args.backend == "device":
        grid = suite.run(mode="train", model=cnn_classifier(28, 10),
                         clients=clients, test_data=(test.x, test.y),
                         horizon_time=args.horizon, batch_size=32,
                         eval_every_time=args.horizon / 40)
        print(f"[fused device engine: {grid.lanes} lanes in "
              f"{grid.programs} compiled programs]")
        for name, logs in grid.entries.items():
            t_hit = float(np.mean([l.time_to_accuracy(args.target)
                                   for l in logs]))
            results[name] = t_hit
            acc = np.mean([l.accuracies[-1] for l in logs])
            upd = int(np.mean([l.updates[-1] for l in logs]))
            m = grid.strategies[name][1]
            print(f"{name:>15}: m={m:3d}  final_acc={acc:.3f}  "
                  f"updates={upd:6d}  t(acc>={args.target})={t_hit:.1f}")
    else:
        for name, scn in suite.scenarios.items():
            tr = AsyncFLTrainer.from_scenario(
                scn, cnn_classifier(28, 10), clients,
                test_data=(test.x, test.y), backend="host", batch_size=32,
                eval_every_time=args.horizon / 40)
            log = tr.run(horizon_time=args.horizon)
            t_hit = log.time_to_accuracy(args.target)
            results[name] = t_hit
            print(f"{name:>15}: m={tr.m:3d}  "
                  f"final_acc={log.accuracies[-1]:.3f}  "
                  f"updates={log.updates[-1]:6d}  "
                  f"t(acc>={args.target})={t_hit:.1f}")

    base_t = results.get("asyncsgd", float("inf"))
    if np.isfinite(results.get("time_opt", np.inf)) and np.isfinite(base_t):
        print(f"\ntime-optimized reaches {args.target:.0%} "
              f"{100 * (1 - results['time_opt'] / base_t):.1f}% faster than "
              f"AsyncSGD (paper Table 3: 29-46%)")


if __name__ == "__main__":
    main()
