"""End-to-end driver: Generalized AsyncSGD training on synthetic-EMNIST.

Reproduces the paper's Section 5.3 comparison (Figure 3 / Table 3): four
scheduling strategies training the same CNN on a Dirichlet(0.2) non-IID
heterogeneous client population, measured in *virtual wall-clock time*.

By default the whole strategies x seeds grid runs on the fused device
engine (``repro.fl.engine``) as ONE jitted, vmapped scan;
``--backend host`` restores the event-at-a-time reference loop driven by
the exact per-task-identity simulator.

Run:  PYTHONPATH=src python examples/async_fl_emnist.py [--horizon 240]
"""
import argparse
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax.numpy as jnp
import numpy as np

from repro.core import LearningConstants
from repro.data import (dirichlet_partition, make_synthetic_image_dataset,
                        train_test_split)
from repro.fl import (AsyncFLConfig, AsyncFLTrainer, cnn_classifier,
                      make_strategies, run_strategy_grid)
from repro.fl.strategies import (PAPER_CLUSTERS_TABLE1,
                                 build_network_params, default_etas)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--horizon", type=float, default=240.0)
    ap.add_argument("--scale", type=int, default=10)
    ap.add_argument("--target", type=float, default=0.6)
    ap.add_argument("--distribution", default="exponential")
    ap.add_argument("--seeds", type=int, default=1,
                    help="seeds per strategy (device backend vmaps them all)")
    ap.add_argument("--backend", choices=("device", "host"), default="device")
    args = ap.parse_args()

    net = build_network_params(PAPER_CLUSTERS_TABLE1, scale=args.scale)
    consts = LearningConstants(L=1, delta=1, sigma=1, M=2, G=5, eps=1)
    strategies = make_strategies(net, consts, steps=200, m_max=net.n + 6)
    etas = default_etas(strategies)

    full = make_synthetic_image_dataset(num_classes=10, samples_per_class=120)
    train, test = train_test_split(full, 0.2, seed=1)
    parts = dirichlet_partition(train.y, net.n, alpha=0.2, seed=0)
    clients = [(train.x[i], train.y[i]) for i in parts]

    results = {}
    if args.backend == "device":
        cfg = AsyncFLConfig(batch_size=32, eval_every_time=args.horizon / 40,
                            distribution=args.distribution, grad_clip=5.0)
        model = cnn_classifier(28, 10)
        grid = run_strategy_grid(model, clients, net, strategies, cfg,
                                 horizon_time=args.horizon,
                                 seeds=tuple(range(args.seeds)), etas=etas,
                                 test_data=(test.x, test.y))
        print(f"[fused device engine: {grid.lanes} lanes x "
              f"{grid.updates_per_lane} scan rounds in one compile]")
        for name, logs in grid.logs.items():
            t_hit = float(np.mean([l.time_to_accuracy(args.target)
                                   for l in logs]))
            results[name] = t_hit
            acc = np.mean([l.accuracies[-1] for l in logs])
            upd = int(np.mean([l.updates[-1] for l in logs]))
            m = strategies[name][1]
            print(f"{name:>15}: m={m:3d}  final_acc={acc:.3f}  "
                  f"updates={upd:6d}  t(acc>={args.target})={t_hit:.1f}")
    else:
        for name, (p, m) in strategies.items():
            model = cnn_classifier(28, 10)
            tr = AsyncFLTrainer(
                model, clients, net._replace(p=jnp.asarray(p)), m,
                config=AsyncFLConfig(eta=etas[name], batch_size=32,
                                     eval_every_time=args.horizon / 40,
                                     distribution=args.distribution,
                                     grad_clip=5.0, backend="host"),
                test_data=(test.x, test.y))
            log = tr.run(horizon_time=args.horizon)
            t_hit = log.time_to_accuracy(args.target)
            results[name] = t_hit
            print(f"{name:>15}: m={m:3d}  final_acc={log.accuracies[-1]:.3f}  "
                  f"updates={log.updates[-1]:6d}  "
                  f"t(acc>={args.target})={t_hit:.1f}")

    base = results.get("asyncsgd", float("inf"))
    if np.isfinite(results.get("time_opt", np.inf)) and np.isfinite(base):
        print(f"\ntime-optimized reaches {args.target:.0%} "
              f"{100 * (1 - results['time_opt'] / base):.1f}% faster than "
              f"AsyncSGD (paper Table 3: 29-46%)")


if __name__ == "__main__":
    main()
