"""Serving example: batched prefill + decode for any assigned architecture.

Uses reduced configs on CPU; on TPU the same code path uses the Pallas
decode-attention kernel (interpret=False is automatic).

Run:  PYTHONPATH=src python examples/serve_decode.py --arch jamba-v0.1-52b
"""
import argparse
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import jax.numpy as jnp
import numpy as np


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="xlstm-350m")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--new-tokens", type=int, default=24)
    args = ap.parse_args()

    from repro.configs import get_config
    from repro.models import build_model

    cfg = get_config(args.arch).reduced(vocab=512)
    bundle = build_model(cfg)
    params = bundle.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    B, P, N = args.batch, args.prompt_len, args.new_tokens
    prompts = jnp.asarray(rng.integers(0, cfg.vocab, (B, P)), jnp.int32)

    cache = bundle.init_cache(B, P + N)
    step = jax.jit(bundle.decode_step)
    logits = None
    for t in range(P):
        logits, cache = step(params, cache, prompts[:, t:t + 1], jnp.int32(t))
    toks = jnp.argmax(logits[:, -1], axis=-1)[:, None].astype(jnp.int32)
    gen = [toks]
    t0 = time.time()
    for t in range(P, P + N - 1):
        logits, cache = step(params, cache, toks, jnp.int32(t))
        toks = jnp.argmax(logits[:, -1], axis=-1)[:, None].astype(jnp.int32)
        gen.append(toks)
    jax.block_until_ready(toks)
    dt = time.time() - t0
    seqs = np.concatenate([np.asarray(t) for t in gen], axis=1)
    print(f"{cfg.name} ({cfg.family}): {B * (N - 1) / dt:.1f} tok/s "
          f"(reduced config, CPU)")
    for b in range(min(B, 2)):
        print(f"  seq{b}: {seqs[b].tolist()}")


if __name__ == "__main__":
    main()
