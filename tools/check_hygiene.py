#!/usr/bin/env python
"""Thin shim over :mod:`repro.analysis.hygiene` (the logic moved there).

Kept so existing entry points (``python tools/check_hygiene.py``, the
tier-1 wrapper in ``tests/test_hygiene.py``) keep working; prefer
``python -m repro.analysis hygiene`` — or plain ``python -m
repro.analysis``, which runs the contract linter too.
"""
from __future__ import annotations

import os
import sys

_SRC = os.path.abspath(
    os.path.join(os.path.dirname(__file__), "..", "src"))
if _SRC not in sys.path:
    sys.path.insert(0, _SRC)

from repro.analysis.hygiene import (  # noqa: E402,F401 — re-exports
    FORBIDDEN,
    FORBIDDEN_SUFFIXES,
    REPO_ROOT,
    main,
    tracked_files,
    tracked_junk,
)

if __name__ == "__main__":
    raise SystemExit(main())
